//! NT03xx — scheme, plan, and sensitivity-profile legality (the `scheme`
//! lint).
//!
//! Three independently callable passes:
//! * [`config_diags`] — the plan alone: method spec, pack widths, duplicate
//!   / out-of-range / grain-drifted layer overrides.
//! * [`artifact_diags`] — the plan against the manifest: exported grains
//!   and the tweak loss's `tweak_step*` graph.  This is exactly the check
//!   `coordinator::validate_scheme_artifacts` runs at pipeline startup
//!   (that function is now a thin wrapper over this pass).
//! * [`profile_diags`] — a persisted `sensitivity.json` against the model
//!   and an `--auto-bits` budget: provenance, candidate widths,
//!   feasibility — every precondition `BitBudgetPlanner::plan` enforces,
//!   but collected instead of fail-fast.

use std::collections::BTreeSet;

use crate::policy::SensitivityProfile;
use crate::quant::quantizer::validate_spec;
use crate::quant::QuantScheme;
use crate::tweak::LossKind;

use super::codes;
use super::diagnostics::{Diagnostic, Report};
use super::{CheckContext, Lint};

pub struct SchemeLint;

/// Plan-only checks: no artifacts needed.
pub fn config_diags(ctx: &CheckContext, report: &mut Report) {
    let Some(plan) = &ctx.plan else { return };
    if let Err(e) = validate_spec(&plan.method) {
        report.push(
            Diagnostic::error(codes::BAD_METHOD, format!("{e}"))
                .field("method")
                .fix("pick a registered quantizer (or a `+`-composition of them)"),
        );
    }
    if let Err(e) = plan.scheme.pack_bits() {
        report.push(
            Diagnostic::error(codes::BAD_PACK_WIDTH, format!("{e}"))
                .field("scheme")
                .fix("use a width with packed storage: 2, 3, 4, or 8 bits"),
        );
    }
    let base_tag = plan.scheme.group_tag();
    let mut seen = BTreeSet::new();
    for &(layer, s) in &plan.layer_schemes {
        let field = format!("layer_bits[{layer}]");
        if !seen.insert(layer) {
            report.push(
                Diagnostic::error(
                    codes::DUP_LAYER_BITS,
                    format!("layer {layer} listed twice in layer_bits"),
                )
                .field(field.clone())
                .fix("keep exactly one override per layer"),
            );
        }
        if let Err(e) = s.pack_bits() {
            report.push(
                Diagnostic::error(codes::BAD_PACK_WIDTH, format!("layer {layer}: {e}"))
                    .field(field.clone())
                    .fix("use a width with packed storage: 2, 3, 4, or 8 bits"),
            );
        }
        if s.group_tag() != base_tag {
            report.push(
                Diagnostic::error(
                    codes::GRAIN_OVERRIDE,
                    format!(
                        "layer {layer} scheme grain {} != base grain {base_tag} \
                         (forward graphs are compiled per grain)",
                        s.group_tag()
                    ),
                )
                .field(field.clone())
                .fix("keep every override at the base scheme's grain"),
            );
        }
        if let Some(cfg) = &ctx.model {
            if layer >= cfg.n_layer {
                report.push(
                    Diagnostic::error(
                        codes::LAYER_RANGE,
                        format!(
                            "layer scheme override for layer {layer}, model has {} \
                             (valid layers: 0..={})",
                            cfg.n_layer,
                            cfg.n_layer - 1
                        ),
                    )
                    .field(field)
                    .fix("drop the out-of-range override"),
                );
            }
        }
    }
}

/// Plan-vs-manifest checks: the grain must have exported graph variants,
/// and a tweaked run needs its loss's `tweak_step*` graph for this model.
/// Mirrors the historical `validate_scheme_artifacts` semantics exactly —
/// including suppressing the graph check when the grain itself is
/// unexported (the graph can't exist either; one finding, not two).
pub fn artifact_diags(ctx: &CheckContext, report: &mut Report) {
    let (Some(plan), Some(manifest)) = (&ctx.plan, &ctx.manifest) else { return };
    let tag = plan.scheme.group_tag();
    if let Err(e) = manifest.validate_grain(&tag) {
        report.push(
            Diagnostic::error(codes::GRAIN_UNEXPORTED, format!("{e}"))
                .at(manifest.dir.join("manifest.json").display().to_string())
                .field("groups")
                .fix(format!("re-run the AOT export with `--groups` including `{tag}`")),
        );
    } else if let (Some(loss), Some(model)) = (&plan.tweak_loss, &ctx.model_name) {
        let graph = loss.graph_name(&tag);
        if manifest.graph(model, &graph).is_err() {
            let note = match loss {
                LossKind::Dist => "",
                _ => "; the Mse/Kl ablation graphs are exported per-channel \
                      for nt-small only",
            };
            report.push(
                Diagnostic::error(
                    codes::TWEAK_GRAPH,
                    format!(
                        "tweak loss {loss:?} at grain `{tag}` needs graph \
                         `{model}.{graph}`, which is not in the manifest \
                         (exported grains: {}{note})",
                        manifest.grain_tags().join(", ")
                    ),
                )
                .at(manifest.dir.join("manifest.json").display().to_string())
                .field("graphs")
                .fix("use an exported loss/grain pair, or re-run the AOT export"),
            );
        }
    }
}

/// Audit a persisted sensitivity profile: readable, internally consistent,
/// provenance-matched to the model and plan, and feasible for the
/// requested `--target-bits` budget.
pub fn profile_diags(ctx: &CheckContext, report: &mut Report) {
    let Some(path) = &ctx.profile_path else { return };
    let origin = path.display().to_string();
    let profile = match SensitivityProfile::load(path) {
        Ok(p) => p,
        Err(e) => {
            report.push(
                Diagnostic::error(
                    codes::PROFILE_INVALID,
                    format!("sensitivity profile unreadable: {e}"),
                )
                .at(origin)
                .fix("re-run `normtweak plan` to regenerate sensitivity.json"),
            );
            return;
        }
    };

    // NT0311: the profile records the checkpoint it was measured against;
    // a re-exported weights file silently invalidates every score.  Only a
    // *definite* mismatch fires — an unreadable weights file (or a profile
    // predating the hash field) is not evidence of drift.
    if let (Some(recorded), Some(wpath)) = (&profile.ckpt_hash, &ctx.weights_path) {
        if let Ok(current) = crate::util::hash::file_hex(wpath) {
            if &current != recorded {
                report.push(
                    Diagnostic::error(
                        codes::PROFILE_STALE,
                        format!(
                            "sensitivity profile was measured against checkpoint \
                             {recorded} but {} now hashes to {current}; every score \
                             is stale",
                            wpath.display()
                        ),
                    )
                    .at(origin.clone())
                    .field("ckpt_hash")
                    .fix("re-run `normtweak plan` against the current checkpoint"),
                );
            }
        }
    }

    if let Some(cfg) = &ctx.model {
        if profile.model != cfg.name {
            report.push(
                Diagnostic::error(
                    codes::PROFILE_MISMATCH,
                    format!(
                        "sensitivity profile was measured for model `{}` but checking \
                         against `{}`",
                        profile.model, cfg.name
                    ),
                )
                .at(origin.clone())
                .field("model")
                .fix("re-run `normtweak plan` for this model"),
            );
        } else if profile.layers.len() != cfg.n_layer {
            report.push(
                Diagnostic::error(
                    codes::PROFILE_MISMATCH,
                    format!(
                        "sensitivity profile covers {} layer(s) but `{}` has {}",
                        profile.layers.len(),
                        cfg.name,
                        cfg.n_layer
                    ),
                )
                .at(origin.clone())
                .field("layers")
                .fix("re-profile with the full model depth"),
            );
        }
    }
    if let Some(plan) = &ctx.plan {
        let base_tag = plan.scheme.group_tag();
        if profile.group_tag != base_tag {
            report.push(
                Diagnostic::error(
                    codes::PROFILE_MISMATCH,
                    format!(
                        "sensitivity profile was measured at grain `{}` but the base \
                         scheme is `{base_tag}`; re-profile at the deployment grain",
                        profile.group_tag
                    ),
                )
                .at(origin.clone())
                .field("group_tag")
                .fix("re-run `normtweak plan` at the deployment grain"),
            );
        }
    }

    if profile.layers.is_empty() {
        report.push(
            Diagnostic::error(codes::PROFILE_INVALID, "sensitivity profile has no layers")
                .at(origin.clone())
                .field("layers")
                .fix("re-run `normtweak plan`"),
        );
    }
    let mut cands = profile.candidate_bits.clone();
    cands.sort_unstable();
    cands.dedup();
    if cands.is_empty() {
        report.push(
            Diagnostic::error(
                codes::PROFILE_INVALID,
                "sensitivity profile has no candidate bit widths",
            )
            .at(origin.clone())
            .field("candidate_bits")
            .fix("re-profile with `--candidates` (supported widths: 2, 3, 4, 8)"),
        );
        return;
    }
    for &bits in &cands {
        if let Err(e) = (QuantScheme { bits, group_size: None }).pack_bits() {
            report.push(
                Diagnostic::error(codes::BAD_PACK_WIDTH, format!("candidate {bits}: {e}"))
                    .at(origin.clone())
                    .field("candidate_bits")
                    .fix("re-profile with supported widths only (2, 3, 4, 8)"),
            );
        }
    }
    if let Some(target) = ctx.target_bits {
        let min_bits = cands[0];
        if target + 1e-6 < min_bits as f32 {
            report.push(
                Diagnostic::error(
                    codes::INFEASIBLE_BUDGET,
                    format!(
                        "target of {target:.2} average bits is below the smallest \
                         candidate width {min_bits} (candidates: {cands:?}) — \
                         infeasible budget",
                    ),
                )
                .at(origin.clone())
                .field("target_bits")
                .fix(format!(
                    "raise --target-bits to at least {min_bits}, or re-profile with \
                     smaller candidates"
                )),
            );
        }
    }
    let mut seen = BTreeSet::new();
    for l in &profile.layers {
        if !seen.insert(l.layer) {
            report.push(
                Diagnostic::error(
                    codes::PROFILE_INVALID,
                    format!("sensitivity profile lists layer {} twice", l.layer),
                )
                .at(origin.clone())
                .field(format!("layers[{}]", l.layer))
                .fix("re-run `normtweak plan`"),
            );
            continue;
        }
        for &bits in &cands {
            if l.score(bits).is_none() {
                report.push(
                    Diagnostic::error(
                        codes::PROFILE_INVALID,
                        format!(
                            "layer {} has no sensitivity score at {bits} bits; \
                             re-profile with the full candidate set",
                            l.layer
                        ),
                    )
                    .at(origin.clone())
                    .field(format!("layers[{}].scores", l.layer))
                    .fix("re-run `normtweak plan` with the full candidate set"),
                );
            }
        }
    }
}

impl Lint for SchemeLint {
    fn name(&self) -> &'static str {
        "scheme"
    }

    fn run(&self, ctx: &CheckContext, report: &mut Report) {
        config_diags(ctx, report);
        artifact_diags(ctx, report);
        profile_diags(ctx, report);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{run_lints, PlanSpec};
    use crate::model::ModelConfig;

    fn plan(scheme: QuantScheme) -> PlanSpec {
        PlanSpec {
            method: "rtn".to_string(),
            scheme,
            layer_schemes: Vec::new(),
            tweak_loss: None,
        }
    }

    #[test]
    fn clean_plan_yields_no_findings() {
        let ctx = CheckContext {
            plan: Some(plan(QuantScheme::w4_g128())),
            model: Some(ModelConfig::builtin("nt-tiny").unwrap()),
            ..CheckContext::default()
        };
        let report = run_lints(&ctx);
        assert!(report.is_empty(), "{:?}", report.codes());
    }

    #[test]
    fn bad_method_duplicate_and_out_of_range_all_collected() {
        let mut p = plan(QuantScheme::w2_g64());
        p.method = "nope".to_string();
        p.layer_schemes = vec![
            (0, QuantScheme { bits: 8, group_size: Some(64) }),
            (0, QuantScheme { bits: 5, group_size: Some(64) }),
            (2, QuantScheme { bits: 4, group_size: None }),
            (9, QuantScheme { bits: 4, group_size: Some(64) }),
        ];
        let ctx = CheckContext {
            plan: Some(p),
            model: Some(ModelConfig::builtin("nt-tiny").unwrap()),
            ..CheckContext::default()
        };
        let codes_seen = run_lints(&ctx).codes();
        for want in [
            codes::BAD_METHOD,
            codes::DUP_LAYER_BITS,
            codes::BAD_PACK_WIDTH,
            codes::GRAIN_OVERRIDE,
            codes::LAYER_RANGE,
        ] {
            assert!(codes_seen.contains(&want), "missing {want} in {codes_seen:?}");
        }
    }

    #[test]
    fn missing_profile_is_nt0310() {
        let ctx = CheckContext {
            profile_path: Some(std::path::PathBuf::from("/definitely/missing.json")),
            ..CheckContext::default()
        };
        assert_eq!(run_lints(&ctx).codes(), vec![codes::PROFILE_INVALID]);
    }

    #[test]
    fn infeasible_budget_mirrors_planner_message() {
        let dir = std::env::temp_dir().join("nt_scheme_lint_budget");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sensitivity.json");
        std::fs::write(
            &path,
            r#"{"model":"nt-tiny","method":"rtn","group_tag":"g64",
                "calib_source":"gen-v2","loss":"dist","candidate_bits":[2,4],
                "layers":[{"layer":0,"scores":{"2":1.0,"4":0.5}},
                          {"layer":1,"scores":{"2":1.0,"4":0.5}}]}"#,
        )
        .unwrap();
        let ctx = CheckContext {
            profile_path: Some(path),
            target_bits: Some(1.5),
            plan: Some(plan(QuantScheme::w2_g64())),
            model: Some(ModelConfig::builtin("nt-tiny").unwrap()),
            ..CheckContext::default()
        };
        let report = run_lints(&ctx);
        assert_eq!(report.codes(), vec![codes::INFEASIBLE_BUDGET]);
        assert!(
            report.diagnostics[0].message.contains("infeasible budget"),
            "{}",
            report.diagnostics[0].message
        );
    }
}

//! NT06xx — search recipe audits (the `recipe` lint).
//!
//! A `recipe.json` is a deployment decision frozen at search time; between
//! then and replay, the artifacts it depends on can drift independently:
//! the AOT export can drop the winning grain (NT0602), the checkpoint can
//! be swapped for a different model (NT0603), the tweak-loss graph can
//! disappear (NT0604), and the sensitivity profile the allocation was
//! planned from can be regenerated with different scores (NT0605).  This
//! lint re-derives each dependency from the live [`CheckContext`] and
//! reports every mismatch, so `quantize --recipe` preflight and
//! `normtweak check --recipe` fail loudly instead of silently deploying a
//! stale allocation.

use crate::search::Recipe;
use crate::util::hash::file_hex;

use super::codes;
use super::diagnostics::{Diagnostic, Report};
use super::{CheckContext, Lint};

pub struct RecipeLint;

/// All NT06xx checks for one recipe path.  No-ops when `ctx.recipe_path`
/// is absent; every other input is optional and gates only its own check.
pub fn recipe_diags(ctx: &CheckContext, report: &mut Report) {
    let Some(path) = &ctx.recipe_path else { return };
    let origin = path.display().to_string();
    let recipe = match Recipe::load(path) {
        Ok(r) => r,
        Err(e) => {
            report.push(
                Diagnostic::error(codes::RECIPE_INVALID, format!("recipe unreadable: {e}"))
                    .at(origin)
                    .fix("re-run `normtweak search` to regenerate the recipe"),
            );
            return;
        }
    };

    // NT0602: the winning grain must still be exported.  When it isn't,
    // the tweak-graph check is suppressed — the graph cannot exist either,
    // and one actionable finding beats two restatements of it (same
    // convention as `scheme_rules::artifact_diags`).
    let tag = recipe.group_tag();
    let mut grain_exported = true;
    if let Some(manifest) = &ctx.manifest {
        if let Err(e) = manifest.validate_grain(&tag) {
            grain_exported = false;
            report.push(
                Diagnostic::error(
                    codes::RECIPE_GRAIN,
                    format!("recipe grain `{tag}` drifted from the manifest: {e}"),
                )
                .at(origin.clone())
                .field("scheme")
                .fix(format!(
                    "re-run the AOT export with `--groups` including `{tag}`, or \
                     re-search against the current artifacts"
                )),
            );
        }
    }

    // NT0603: the recipe must describe the model it is replayed against —
    // by name, and by depth (a plan layer past the architecture would be
    // rejected by the pipeline anyway, but here it is attributed to the
    // recipe, not the flag that loaded it).
    if let Some(cfg) = &ctx.model {
        if recipe.model != cfg.name {
            report.push(
                Diagnostic::error(
                    codes::RECIPE_MODEL,
                    format!(
                        "recipe was searched for model `{}` but checking against `{}`",
                        recipe.model, cfg.name
                    ),
                )
                .at(origin.clone())
                .field("model")
                .fix("re-run `normtweak search` for this model"),
            );
        } else if let Some((&layer, _)) =
            recipe.plan.schemes.iter().find(|(&l, _)| l >= cfg.n_layer)
        {
            report.push(
                Diagnostic::error(
                    codes::RECIPE_MODEL,
                    format!(
                        "recipe plan allocates layer {layer}, but `{}` has {} layer(s)",
                        cfg.name, cfg.n_layer
                    ),
                )
                .at(origin.clone())
                .field(format!("plan.layers[{layer}]"))
                .fix("re-run `normtweak search` for this model"),
            );
        }
    }

    // NT0604: a tweaked recipe needs its loss's `tweak_step*` graph for
    // this model at the winning grain.
    if grain_exported {
        if let (Some(tweak), Some(manifest), Some(model)) =
            (&recipe.tweak, &ctx.manifest, &ctx.model_name)
        {
            let graph = tweak.loss.graph_name(&tag);
            if manifest.graph(model, &graph).is_err() {
                report.push(
                    Diagnostic::error(
                        codes::RECIPE_TWEAK_GRAPH,
                        format!(
                            "recipe tweaks with loss {:?} at grain `{tag}`, which needs \
                             graph `{model}.{graph}` — not in the manifest (exported \
                             grains: {})",
                            tweak.loss,
                            manifest.grain_tags().join(", ")
                        ),
                    )
                    .at(origin.clone())
                    .field("tweak")
                    .fix("use an exported loss/grain pair, or re-run the AOT export"),
                );
            }
        }
    }

    // NT0605: the profile the allocation was planned from must still be
    // the file the recipe hashed.  The recorded path is tried as-is, then
    // relative to the recipe's own directory (recipes are meant to move
    // together with their profile).
    let recorded = std::path::Path::new(&recipe.provenance.profile_path);
    let resolved = if recorded.exists() {
        Some(recorded.to_path_buf())
    } else {
        path.parent()
            .map(|d| d.join(recorded))
            .filter(|p| p.exists())
    };
    match resolved {
        None => {
            report.push(
                Diagnostic::error(
                    codes::RECIPE_PROFILE_STALE,
                    format!(
                        "recipe's sensitivity profile `{}` not found (tried as-is and \
                         relative to the recipe)",
                        recipe.provenance.profile_path
                    ),
                )
                .at(origin)
                .field("provenance.profile_path")
                .fix("restore the profile next to the recipe, or re-search"),
            );
        }
        Some(p) => match file_hex(&p) {
            Ok(h) if h == recipe.provenance.profile_hash => {}
            Ok(h) => {
                report.push(
                    Diagnostic::error(
                        codes::RECIPE_PROFILE_STALE,
                        format!(
                            "recipe planned from profile {} (hash {}), but {} now \
                             hashes to {h}; the allocation no longer reflects the \
                             measured sensitivities",
                            recipe.provenance.profile_path,
                            recipe.provenance.profile_hash,
                            p.display()
                        ),
                    )
                    .at(origin)
                    .field("provenance.profile_hash")
                    .fix("re-run `normtweak search` against the current profile"),
                );
            }
            Err(e) => {
                report.push(
                    Diagnostic::error(
                        codes::RECIPE_PROFILE_STALE,
                        format!("recipe's sensitivity profile unreadable: {e}"),
                    )
                    .at(origin)
                    .field("provenance.profile_path")
                    .fix("restore a readable profile, or re-search"),
                );
            }
        },
    }
}

impl Lint for RecipeLint {
    fn name(&self) -> &'static str {
        "recipe"
    }

    fn run(&self, ctx: &CheckContext, report: &mut Report) {
        recipe_diags(ctx, report);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::run_lints;

    #[test]
    fn no_recipe_no_findings() {
        let mut report = Report::new();
        recipe_diags(&CheckContext::default(), &mut report);
        assert!(report.is_empty());
    }

    #[test]
    fn missing_recipe_is_nt0601() {
        let ctx = CheckContext {
            recipe_path: Some(std::path::PathBuf::from("/definitely/missing/recipe.json")),
            ..CheckContext::default()
        };
        assert_eq!(run_lints(&ctx).codes(), vec![codes::RECIPE_INVALID]);
    }

    #[test]
    fn garbage_recipe_is_nt0601() {
        let dir = std::env::temp_dir().join("nt_recipe_lint_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.json");
        std::fs::write(&path, "{").unwrap();
        let ctx = CheckContext { recipe_path: Some(path), ..CheckContext::default() };
        assert_eq!(run_lints(&ctx).codes(), vec![codes::RECIPE_INVALID]);
    }
}

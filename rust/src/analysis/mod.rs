//! Pre-flight static analysis — the `normtweak check` subsystem.
//!
//! Every failure mode the runtime validates reactively (unexported grains,
//! malformed manifests, decode-cache drift, infeasible bit plans, degenerate
//! serve tunings) is statically decidable from the artifacts on disk.  This
//! module cross-checks checkpoint ↔ manifest ↔ scheme ↔ decode spec ↔
//! engine config *before* any XLA client exists, and — unlike the
//! fail-fast `validate()` functions it subsumes — collects **all** findings
//! in one run as [`Diagnostic`]s with stable codes.
//!
//! # Architecture
//!
//! Each rule is a one-file plugin implementing the [`Lint`] trait,
//! registered in [`LINT_REGISTRY`] — the same registry idiom as
//! `quant::quantizer::REGISTRY`.  A rule reads whatever slice of the
//! [`CheckContext`] it understands and no-ops when its inputs are absent,
//! so one context drives `check` (everything), `quantize`
//! (`coordinator::validate_scheme_artifacts`, now lint-backed), `plan`, and
//! `serve` startup.  [`Report::into_result`] converts a collected report
//! back into the crate's fail-fast world, preserving the historical
//! first-error behavior (an `Err` that aborts) while carrying the full
//! diagnostic list.
//!
//! # Diagnostic codes
//!
//! Codes are stable; CI and the golden-fixture suite
//! (`rust/tests/analysis_lint.rs`) gate on them.
//!
//! | code | severity | meaning | suggested fix |
//! |--------|---------|---------|---------------|
//! | NT0101 | error | `manifest.json` missing or unreadable | run `make artifacts` |
//! | NT0102 | error | `manifest.json` is not valid JSON | re-run the AOT export |
//! | NT0103 | error | required manifest key missing or mistyped (incl. `format` != 1) | re-run the AOT export |
//! | NT0104 | error | `buckets` empty, non-array, or non-numeric | re-export with a valid bucket set |
//! | NT0105 | error | `groups` malformed or tag↔size drift (e.g. `{"g32": 64}`) | re-export with consistent `--groups` |
//! | NT0106 | error | `decode` record malformed (buckets, caches, cache-shape rank) | re-export the decode graphs |
//! | NT0107 | error | decode buckets cannot fit the largest main bucket | re-export with matching bucket sets |
//! | NT0108 | warning | a graph's HLO file is listed but missing on disk | re-run `make artifacts` |
//! | NT0109 | error | duplicate `(model, graph)` entry in `graphs` | re-run the AOT export |
//! | NT0110 | error | `decode.slots` incompatible with the slot arena (below the largest decode bucket, or no exported step graph at that batch) | re-export with `slots` in `decode.buckets` |
//! | NT0201 | error | checkpoint `.ntz` missing or unreadable | re-run `normtweak quantize` |
//! | NT0202 | error | required checkpoint tensor missing or mistyped | re-quantize the checkpoint |
//! | NT0203 | error | packed codes don't round-trip (bad `pbits` width or byte length) | re-quantize the checkpoint |
//! | NT0204 | error | linear/scale geometry disagrees with the architecture | re-quantize for this model |
//! | NT0205 | error | checkpoint grain has no exported graphs | re-export with the grain in `--groups` |
//! | NT0206 | error | model missing from the manifest's `models` record | re-export including the model |
//! | NT0207 | error | manifest model record drifts from the Rust registry | re-export or fix the registry |
//! | NT0208 | error | decode cache spec `[H, S, dh]` disagrees with the architecture | re-run the AOT export |
//! | NT0301 | error | unknown or invalid quantizer method spec | pick a registered method |
//! | NT0302 | error | duplicate layer index in `layer_bits` | keep one override per layer |
//! | NT0303 | error | bit width has no packed storage (supported: 2, 3, 4, 8) | pick a supported width |
//! | NT0304 | error | layer override grain differs from the base grain | keep overrides at the base grain |
//! | NT0305 | error | layer override beyond the model depth | drop the out-of-range override |
//! | NT0306 | error | `--target-bits` below the smallest profiled candidate | raise the budget or re-profile |
//! | NT0307 | error | sensitivity profile provenance mismatch (model / layers / grain) | re-run `normtweak plan` |
//! | NT0308 | error | scheme grain has no exported graphs | re-export with the grain in `--groups` |
//! | NT0309 | error | tweak-loss graph missing for this (loss, grain) | use an exported loss/grain pair |
//! | NT0310 | error | sensitivity profile unreadable or internally inconsistent | re-run `normtweak plan` |
//! | NT0311 | error | profile's recorded checkpoint hash drifts from `weights_<model>.ntz` | re-profile against the current checkpoint |
//! | NT0401 | error | `max_batch` is 0 | use `max_batch >= 1` |
//! | NT0402 | error | `batch_window` is zero | use a window >= 1ms |
//! | NT0403 | warning | `max_batch` exceeds the largest exported batch bucket | lower `max_batch` or re-export |
//! | NT0404 | warning | deadline shorter than the batch window | raise the deadline or shrink the window |
//! | NT0405 | error | malformed `--serve-config` / `--models` entry | use the accepted keys/format |
//! | NT0501 | error | HLO file unreadable, empty, or has no parseable ENTRY signature (deep mode) | re-run `make artifacts` |
//! | NT0502 | error | exporter-recorded signature drifts from the lowered HLO (per parameter) | re-run the AOT export |
//! | NT0503 | error | quantized-block argument list / packed-code / scale geometry mismatch | re-export with a consistent grain |
//! | NT0504 | error | pipeline dataflow type mismatch (embed/block/head streams, bucket drift) | re-run the AOT export |
//! | NT0505 | error | prefill-KV results drift from the manifest decode cache spec `[H, S, dh]` | re-export the decode graphs |
//! | NT0506 | error | decode step violates the `pos i32[B]` / carried-cache contract | re-export the decode graphs |
//! | NT0507 | error | tweak-loss graph does not end in a `f32[1]` loss | re-run the AOT export |
//! | NT0508 | info | graph skipped: no contract reconstructable (unknown family/model) | — |
//! | NT0509 | warning | no recorded output signature and no parseable HLO to check against | re-export to record `outputs` |
//! | NT0601 | error | recipe unreadable, unparseable, or internally inconsistent | re-run `normtweak search` |
//! | NT0602 | error | recipe grain has no exported graphs (recipe ↔ manifest drift) | re-export with the grain, or re-search |
//! | NT0603 | error | recipe model drifts from the checked model / architecture | re-run `normtweak search` for this model |
//! | NT0604 | error | recipe's tweak-loss graph missing for its (loss, grain) | use an exported loss/grain pair, or re-search |
//! | NT0605 | error | recipe's sensitivity profile missing or content-drifted | re-profile and re-search |
//!
//! NT05xx fire only in **deep** mode (`check --graphs`, or the
//! `--deep-check` preflight of `quantize`/`serve`): the `graphs` lint
//! parses every HLO ENTRY signature and verifies the reconstructed
//! pipeline dataflow — see [`graph_rules`].
//!
//! # CLI
//!
//! ```text
//! normtweak check [--manifest DIR] [--ckpt q.ntz] [--scheme gptq:w4g64]
//!                 [--layer-bits 0:8,3:2] [--no-tweak]
//!                 [--profile sensitivity.json] [--target-bits 2.25]
//!                 [--recipe recipe.json]
//!                 [--serve-config max_batch=8,batch_window_ms=2,deadline_ms=500]
//!                 [--models w4=a.ntz] [--graphs]
//!                 [--format human|json] [--deny-warnings]
//! ```
//!
//! Exit status is non-zero on any error-severity finding, and on warnings
//! too under `--deny-warnings`; `--format json` emits the whole report
//! through `util::json` so CI can gate on codes.

pub mod checkpoint_rules;
pub mod diagnostics;
pub mod graph_rules;
pub mod hlo;
pub mod manifest_rules;
pub mod recipe_rules;
pub mod scheme_rules;
pub mod serve_rules;

use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::model::ModelConfig;
use crate::quant::QuantScheme;
use crate::runtime::ArtifactManifest;
use crate::tweak::LossKind;

pub use diagnostics::{Diagnostic, Report, Severity};

/// Stable diagnostic codes.  [`ALL`](codes::ALL) is the authoritative list;
/// the golden-fixture suite asserts every entry fires on a corrupted
/// fixture and appears in the module-docs table above.
pub mod codes {
    pub const MANIFEST_UNREADABLE: &str = "NT0101";
    pub const MANIFEST_PARSE: &str = "NT0102";
    pub const MANIFEST_KEY: &str = "NT0103";
    pub const MANIFEST_BUCKETS: &str = "NT0104";
    pub const MANIFEST_GROUPS: &str = "NT0105";
    pub const DECODE_RECORD: &str = "NT0106";
    pub const DECODE_BUCKET_GAP: &str = "NT0107";
    pub const GRAPH_FILE_MISSING: &str = "NT0108";
    pub const GRAPH_DUPLICATE: &str = "NT0109";
    pub const ARENA_SLOTS: &str = "NT0110";
    pub const CKPT_UNREADABLE: &str = "NT0201";
    pub const CKPT_TENSOR: &str = "NT0202";
    pub const CKPT_PACK: &str = "NT0203";
    pub const CKPT_GEOMETRY: &str = "NT0204";
    pub const CKPT_GRAIN: &str = "NT0205";
    pub const MODEL_UNKNOWN: &str = "NT0206";
    pub const MODEL_DRIFT: &str = "NT0207";
    pub const DECODE_CACHE_DRIFT: &str = "NT0208";
    pub const BAD_METHOD: &str = "NT0301";
    pub const DUP_LAYER_BITS: &str = "NT0302";
    pub const BAD_PACK_WIDTH: &str = "NT0303";
    pub const GRAIN_OVERRIDE: &str = "NT0304";
    pub const LAYER_RANGE: &str = "NT0305";
    pub const INFEASIBLE_BUDGET: &str = "NT0306";
    pub const PROFILE_MISMATCH: &str = "NT0307";
    pub const GRAIN_UNEXPORTED: &str = "NT0308";
    pub const TWEAK_GRAPH: &str = "NT0309";
    pub const PROFILE_INVALID: &str = "NT0310";
    pub const PROFILE_STALE: &str = "NT0311";
    pub const ZERO_MAX_BATCH: &str = "NT0401";
    pub const ZERO_BATCH_WINDOW: &str = "NT0402";
    pub const BATCH_OVER_BUCKET: &str = "NT0403";
    pub const DEADLINE_WINDOW: &str = "NT0404";
    pub const BAD_SERVE_SPEC: &str = "NT0405";
    pub const GRAPH_HLO_INVALID: &str = "NT0501";
    pub const GRAPH_SIG_DRIFT: &str = "NT0502";
    pub const GRAPH_QARGS: &str = "NT0503";
    pub const GRAPH_DATAFLOW: &str = "NT0504";
    pub const GRAPH_KV_SPEC: &str = "NT0505";
    pub const GRAPH_DECODE_STEP: &str = "NT0506";
    pub const GRAPH_TWEAK_LOSS: &str = "NT0507";
    pub const GRAPH_SKIPPED: &str = "NT0508";
    pub const GRAPH_NO_OUTPUTS: &str = "NT0509";
    pub const RECIPE_INVALID: &str = "NT0601";
    pub const RECIPE_GRAIN: &str = "NT0602";
    pub const RECIPE_MODEL: &str = "NT0603";
    pub const RECIPE_TWEAK_GRAPH: &str = "NT0604";
    pub const RECIPE_PROFILE_STALE: &str = "NT0605";

    /// Every stable code with its one-line meaning, in code order.
    pub const ALL: &[(&str, &str)] = &[
        (MANIFEST_UNREADABLE, "manifest.json missing or unreadable"),
        (MANIFEST_PARSE, "manifest.json is not valid JSON"),
        (MANIFEST_KEY, "required manifest key missing or mistyped"),
        (MANIFEST_BUCKETS, "buckets empty, non-array, or non-numeric"),
        (MANIFEST_GROUPS, "groups malformed or tag/size drift"),
        (DECODE_RECORD, "decode record malformed"),
        (DECODE_BUCKET_GAP, "decode buckets cannot fit the largest main bucket"),
        (GRAPH_FILE_MISSING, "graph HLO file listed but missing on disk"),
        (GRAPH_DUPLICATE, "duplicate (model, graph) entry in graphs"),
        (ARENA_SLOTS, "decode.slots incompatible with the slot arena"),
        (CKPT_UNREADABLE, "checkpoint .ntz missing or unreadable"),
        (CKPT_TENSOR, "required checkpoint tensor missing or mistyped"),
        (CKPT_PACK, "packed codes do not round-trip"),
        (CKPT_GEOMETRY, "linear/scale geometry disagrees with the architecture"),
        (CKPT_GRAIN, "checkpoint grain has no exported graphs"),
        (MODEL_UNKNOWN, "model missing from the manifest models record"),
        (MODEL_DRIFT, "manifest model record drifts from the Rust registry"),
        (DECODE_CACHE_DRIFT, "decode cache spec disagrees with the architecture"),
        (BAD_METHOD, "unknown or invalid quantizer method spec"),
        (DUP_LAYER_BITS, "duplicate layer index in layer_bits"),
        (BAD_PACK_WIDTH, "bit width has no packed storage"),
        (GRAIN_OVERRIDE, "layer override grain differs from the base grain"),
        (LAYER_RANGE, "layer override beyond the model depth"),
        (INFEASIBLE_BUDGET, "target-bits below the smallest profiled candidate"),
        (PROFILE_MISMATCH, "sensitivity profile provenance mismatch"),
        (GRAIN_UNEXPORTED, "scheme grain has no exported graphs"),
        (TWEAK_GRAPH, "tweak-loss graph missing for this loss/grain"),
        (PROFILE_INVALID, "sensitivity profile unreadable or inconsistent"),
        (PROFILE_STALE, "profile's checkpoint hash drifts from the weights file"),
        (ZERO_MAX_BATCH, "max_batch is 0"),
        (ZERO_BATCH_WINDOW, "batch_window is zero"),
        (BATCH_OVER_BUCKET, "max_batch exceeds the largest exported bucket"),
        (DEADLINE_WINDOW, "deadline shorter than the batch window"),
        (BAD_SERVE_SPEC, "malformed serve-config or models entry"),
        (GRAPH_HLO_INVALID, "HLO file unreadable, empty, or signature-free"),
        (GRAPH_SIG_DRIFT, "recorded signature drifts from the lowered HLO"),
        (GRAPH_QARGS, "quantized-block argument/scale geometry mismatch"),
        (GRAPH_DATAFLOW, "pipeline dataflow type mismatch"),
        (GRAPH_KV_SPEC, "prefill-KV results drift from the decode cache spec"),
        (GRAPH_DECODE_STEP, "decode step violates the pos/carried-cache contract"),
        (GRAPH_TWEAK_LOSS, "tweak-loss graph does not end in a scalar loss"),
        (GRAPH_SKIPPED, "graph skipped: no contract reconstructable"),
        (GRAPH_NO_OUTPUTS, "no recorded output signature and no parseable HLO"),
        (RECIPE_INVALID, "recipe unreadable, unparseable, or inconsistent"),
        (RECIPE_GRAIN, "recipe grain has no exported graphs"),
        (RECIPE_MODEL, "recipe model drifts from the checked model"),
        (RECIPE_TWEAK_GRAPH, "recipe's tweak-loss graph missing for its loss/grain"),
        (RECIPE_PROFILE_STALE, "recipe's sensitivity profile missing or drifted"),
    ];
}

/// The scheme/plan slice of a check: what the pipeline is about to run.
#[derive(Debug, Clone)]
pub struct PlanSpec {
    /// Quantizer spec (any registered name or `+`-composition).
    pub method: String,
    /// Base scheme; overrides must share its grain.
    pub scheme: QuantScheme,
    /// Per-layer overrides in declaration order — kept as a `Vec` (not a
    /// map) so duplicate indices survive to be reported as NT0302.
    pub layer_schemes: Vec<(usize, QuantScheme)>,
    /// `Some` when the run tweaks (the loss's `tweak_step*` graph must be
    /// exported); `None` for plain PTQ.
    pub tweak_loss: Option<LossKind>,
}

/// The serve-config slice of a check, kept as the raw CLI strings so the
/// serve lint can report malformed keys/entries (NT0405) itself instead of
/// dying in a parser.
#[derive(Debug, Clone, Default)]
pub struct ServeCheck {
    /// `--serve-config max_batch=8,batch_window_ms=2,deadline_ms=500`;
    /// omitted keys take [`crate::engine::ModelTuning::default`] values.
    pub spec: Option<String>,
    /// `--models w4=a.ntz,w2=b.ntz`.
    pub models_spec: Option<String>,
}

/// Everything a lint may look at.  All slices are optional: a rule no-ops
/// on absent inputs, so one context type serves `check` (everything) and
/// the per-command startup subsets.
#[derive(Debug, Clone, Default)]
pub struct CheckContext {
    /// Artifacts directory whose `manifest.json` the manifest lint walks
    /// raw (collecting every schema violation, not just the first).
    pub manifest_dir: Option<PathBuf>,
    /// Parsed manifest for cross-checks (grains, buckets, models, decode).
    /// Callers populate it when `ArtifactManifest::load` succeeded; the
    /// raw walk still reports *why* a load failed.
    pub manifest: Option<ArtifactManifest>,
    /// Quantized checkpoint to cross-check against manifest + architecture.
    pub ckpt_path: Option<PathBuf>,
    /// Target architecture (drives geometry and decode-cache checks).
    pub model: Option<ModelConfig>,
    /// Model name for manifest graph lookups (usually `model.name`).
    pub model_name: Option<String>,
    /// Scheme/plan under check.
    pub plan: Option<PlanSpec>,
    /// Persisted sensitivity profile (`sensitivity.json`) to audit.
    pub profile_path: Option<PathBuf>,
    /// `--auto-bits` / `--target-bits` budget to test for feasibility
    /// against the profile's candidates.
    pub target_bits: Option<f32>,
    /// Search recipe (`recipe.json`) to audit against the manifest, model,
    /// and its recorded profile provenance (NT06xx).
    pub recipe_path: Option<PathBuf>,
    /// Float checkpoint (`weights_<model>.ntz`) the profile's recorded
    /// `ckpt_hash` is verified against (NT0311); absent = skip the check.
    pub weights_path: Option<PathBuf>,
    /// Engine/serve tuning under check.
    pub serve: Option<ServeCheck>,
    /// Deep mode: run the NT05xx `graphs` lint (parse every HLO ENTRY
    /// signature and verify the reconstructed pipeline dataflow).  Off by
    /// default — deep mode reads every graph file, so `check` opts in via
    /// `--graphs` and `quantize`/`serve` via `--deep-check`.
    pub graphs: bool,
}

/// One static-analysis rule.  Mirrors `quant::quantizer::Quantizer`:
/// implement the trait in a file under `analysis/` and add a
/// [`LintRegistration`] row to [`LINT_REGISTRY`].
pub trait Lint {
    /// Registry name (`"manifest"`, `"checkpoint"`, ...).
    fn name(&self) -> &'static str;
    /// Inspect `ctx` and push findings; collect everything, never fail
    /// fast — severity decides what aborts downstream.
    fn run(&self, ctx: &CheckContext, report: &mut Report);
}

/// One registry row — the lint-side analog of
/// `quant::quantizer::Registration`.
pub struct LintRegistration {
    pub name: &'static str,
    pub summary: &'static str,
    pub build: fn() -> Box<dyn Lint>,
}

fn build_manifest() -> Box<dyn Lint> {
    Box::new(manifest_rules::ManifestLint)
}

fn build_checkpoint() -> Box<dyn Lint> {
    Box::new(checkpoint_rules::CheckpointLint)
}

fn build_scheme() -> Box<dyn Lint> {
    Box::new(scheme_rules::SchemeLint)
}

fn build_serve() -> Box<dyn Lint> {
    Box::new(serve_rules::ServeLint)
}

fn build_graphs() -> Box<dyn Lint> {
    Box::new(graph_rules::GraphLint)
}

fn build_recipe() -> Box<dyn Lint> {
    Box::new(recipe_rules::RecipeLint)
}

/// The built-in rule set, in run order (NT01xx → NT06xx).
pub const LINT_REGISTRY: &[LintRegistration] = &[
    LintRegistration {
        name: "manifest",
        summary: "manifest.json schema, grain/bucket consistency, graph files",
        build: build_manifest,
    },
    LintRegistration {
        name: "checkpoint",
        summary: "checkpoint tensors, pack-width round-trips, manifest cross-checks",
        build: build_checkpoint,
    },
    LintRegistration {
        name: "scheme",
        summary: "method/scheme/plan legality, profile feasibility, exported grains",
        build: build_scheme,
    },
    LintRegistration {
        name: "serve",
        summary: "engine tuning sanity vs exported batch buckets",
        build: build_serve,
    },
    LintRegistration {
        name: "graphs",
        summary: "deep mode: HLO ENTRY signatures vs the reconstructed pipeline dataflow",
        build: build_graphs,
    },
    LintRegistration {
        name: "recipe",
        summary: "search recipe vs manifest grain, model, tweak graphs, profile provenance",
        build: build_recipe,
    },
];

/// The registered lints (by reference, like `quant::registry`).
pub fn registry() -> &'static [LintRegistration] {
    LINT_REGISTRY
}

/// Registered lint names, in run order.
pub fn registered_lints() -> Vec<&'static str> {
    LINT_REGISTRY.iter().map(|r| r.name).collect()
}

/// Run every registered lint over `ctx`, collecting all findings.
pub fn run_lints(ctx: &CheckContext) -> Report {
    let mut report = Report::new();
    for reg in LINT_REGISTRY {
        (reg.build)().run(ctx, &mut report);
    }
    report
}

/// Startup gate for the CLI commands: run every lint, log non-error
/// findings (warnings at `warn`, notes at `info` — both through
/// [`crate::obs::log`], so `NORMTWEAK_LOG=error` silences them), and abort
/// with the full error list (wrapped in [`Error::Config`]) when anything
/// error-severity fired.
pub fn preflight(ctx: &CheckContext) -> Result<()> {
    let report = run_lints(ctx);
    for d in &report.diagnostics {
        match d.severity {
            Severity::Error => {}
            Severity::Warn => crate::log_warn!("check", "[{}] {}", d.code, d.message),
            Severity::Info => crate::log_info!("check", "[{}] {}", d.code, d.message),
        }
    }
    report.into_result(Error::Config)
}

/// Parse a `[method:]w<bits><pc|g<N>>` scheme spec (`gptq:w4g64`, `w3pc`,
/// `smoothquant+gptq:w2g32`; the grain suffix defaults to per-channel).
/// Returns the optional method and the scheme.  A malformed spec is an
/// immediate [`Error::Config`] naming the expected format — the flag
/// itself, not the artifacts, is broken.
pub fn parse_scheme_spec(spec: &str) -> Result<(Option<String>, QuantScheme)> {
    let bad = || {
        Error::Config(format!(
            "bad scheme spec `{spec}`: expected `[method:]w<bits><pc|g<N>>` \
             (e.g. `gptq:w4g64`, `w3pc`, `w2g32`)"
        ))
    };
    let (method, body) = match spec.rsplit_once(':') {
        Some((m, b)) if !m.is_empty() => (Some(m.to_string()), b),
        Some(_) => return Err(bad()),
        None => (None, spec),
    };
    let digits_and_grain = body.strip_prefix('w').ok_or_else(bad)?;
    let split = digits_and_grain
        .find(|c: char| !c.is_ascii_digit())
        .unwrap_or(digits_and_grain.len());
    let (bits_str, grain) = digits_and_grain.split_at(split);
    let bits: u8 = bits_str.parse().map_err(|_| bad())?;
    let group_size = match grain {
        "" | "pc" => None,
        g => Some(g.strip_prefix('g').ok_or_else(bad)?.parse().map_err(|_| bad())?),
    };
    Ok((method, QuantScheme { bits, group_size }))
}

/// Parse `--layer-bits 0:8,3:2` into per-layer overrides at the base
/// scheme's grain.  Deliberately lenient about duplicate layer indices —
/// they survive into [`PlanSpec::layer_schemes`] so the scheme lint can
/// report NT0302 alongside every other finding (the strict config-file
/// parser, `Config::layer_schemes`, still fail-fasts).
pub fn parse_layer_bits(spec: &str, base: QuantScheme) -> Result<Vec<(usize, QuantScheme)>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let part = part.trim();
        let (layer, bits) = part.split_once(':').ok_or_else(|| {
            Error::Config(format!(
                "bad layer_bits entry `{part}`: expected `layer:bits` (e.g. `0:8,3:2`)"
            ))
        })?;
        let layer: usize = layer.trim().parse().map_err(|_| {
            Error::Config(format!(
                "bad layer_bits entry `{part}`: layer index `{}` is not a number",
                layer.trim()
            ))
        })?;
        let bits: u8 = bits.trim().parse().map_err(|_| {
            Error::Config(format!(
                "bad layer_bits entry `{part}`: bit width `{}` is not a number",
                bits.trim()
            ))
        })?;
        out.push((layer, QuantScheme { bits, group_size: base.group_size }));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lists_every_lint() {
        assert_eq!(
            registered_lints(),
            vec!["manifest", "checkpoint", "scheme", "serve", "graphs", "recipe"]
        );
        for reg in registry() {
            assert_eq!((reg.build)().name(), reg.name);
            assert!(!reg.summary.is_empty());
        }
    }

    #[test]
    fn codes_are_unique_and_sorted() {
        let mut seen = std::collections::BTreeSet::new();
        let mut prev = "";
        for (code, meaning) in codes::ALL {
            assert!(seen.insert(*code), "duplicate code {code}");
            assert!(*code > prev, "codes::ALL out of order at {code}");
            assert!(!meaning.is_empty());
            prev = code;
        }
    }

    #[test]
    fn empty_context_is_clean() {
        let report = run_lints(&CheckContext::default());
        assert!(report.is_empty(), "{:?}", report.codes());
        assert!(preflight(&CheckContext::default()).is_ok());
    }

    #[test]
    fn scheme_spec_parses() {
        let (m, s) = parse_scheme_spec("gptq:w4g64").unwrap();
        assert_eq!(m.as_deref(), Some("gptq"));
        assert_eq!(s, QuantScheme { bits: 4, group_size: Some(64) });
        let (m, s) = parse_scheme_spec("w3pc").unwrap();
        assert!(m.is_none());
        assert_eq!(s, QuantScheme { bits: 3, group_size: None });
        let (m, s) = parse_scheme_spec("smoothquant+gptq:w2g32").unwrap();
        assert_eq!(m.as_deref(), Some("smoothquant+gptq"));
        assert_eq!(s, QuantScheme { bits: 2, group_size: Some(32) });
        // bare width defaults to per-channel
        let (_, s) = parse_scheme_spec("w8").unwrap();
        assert_eq!(s.group_size, None);
    }

    #[test]
    fn scheme_spec_rejects_malformed() {
        for bad in ["", "4g64", "wxg64", "w4q64", "w4g", ":w4", "w4gsixty"] {
            let err = parse_scheme_spec(bad).unwrap_err();
            assert!(format!("{err}").contains("w<bits><pc|g<N>>"), "{bad}: {err}");
        }
    }

    #[test]
    fn layer_bits_keeps_duplicates_for_the_lint() {
        let base = QuantScheme::w2_g64();
        let got = parse_layer_bits("0:8, 1:4,0:2", base).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], (0, QuantScheme { bits: 8, group_size: Some(64) }));
        assert_eq!(got[2].0, 0);
        assert!(parse_layer_bits("0", base).is_err());
        assert!(parse_layer_bits("a:4", base).is_err());
        assert!(parse_layer_bits("0:b", base).is_err());
    }
}

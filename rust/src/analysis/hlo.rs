//! HLO entry-signature parsing + the shared tensor-signature types.
//!
//! The AOT exporter writes every graph as HLO *text* (see `aot.py`); the
//! only part of that text the static analyses need is the ENTRY signature —
//! parameter and result shapes/dtypes.  [`parse_signature`] extracts it
//! from the `entry_computation_layout={...}` header (with an ENTRY-body
//! fallback for files that lost the header), producing the same
//! [`TensorSig`] type the runtime's argument validation uses
//! (`runtime::literal::check_spec`), so the `graphs` lint and the runtime
//! guard can never disagree about what a shape means.
//!
//! Signatures only: no op parsing, no layout checking (`{1,0}` suffixes are
//! skipped), no computation bodies.

use crate::error::{Error, Result};
use crate::tensor::{DType, Tensor};

/// The dtypes that can cross the exporter ↔ runtime boundary.  Two spelling
/// domains map onto it: manifest strings (`"i32"`, `"i8"`, ...) and HLO
/// element types (`s32`, `s8`, ...).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SigDType {
    F32,
    I8,
    U8,
    I32,
    I64,
}

impl SigDType {
    /// Parse a manifest dtype string (`"f32"`, `"i8"`, `"u8"`, `"i32"`,
    /// `"i64"`).
    pub fn from_manifest(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(SigDType::F32),
            "i8" => Some(SigDType::I8),
            "u8" => Some(SigDType::U8),
            "i32" => Some(SigDType::I32),
            "i64" => Some(SigDType::I64),
            _ => None,
        }
    }

    /// Parse an HLO element-type token (`f32`, `s8`, `u8`, `s32`, `s64`).
    pub fn from_hlo(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(SigDType::F32),
            "s8" => Some(SigDType::I8),
            "u8" => Some(SigDType::U8),
            "s32" => Some(SigDType::I32),
            "s64" => Some(SigDType::I64),
            _ => None,
        }
    }

    /// The manifest spelling (`"f32"`, `"i8"`, ...).
    pub fn as_manifest(&self) -> &'static str {
        match self {
            SigDType::F32 => "f32",
            SigDType::I8 => "i8",
            SigDType::U8 => "u8",
            SigDType::I32 => "i32",
            SigDType::I64 => "i64",
        }
    }

    /// The tensor-storage dtype this signature dtype validates against.
    pub fn dtype(&self) -> DType {
        match self {
            SigDType::F32 => DType::F32,
            SigDType::I8 => DType::I8,
            SigDType::U8 => DType::U8,
            SigDType::I32 => DType::I32,
            SigDType::I64 => DType::I64,
        }
    }
}

/// One tensor signature: dtype + dims.  The shared currency of the `graphs`
/// lint, the manifest's recorded `inputs`/`outputs`, and the runtime's
/// per-call argument validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    pub dtype: SigDType,
    pub dims: Vec<usize>,
}

impl TensorSig {
    pub fn new(dtype: SigDType, dims: impl Into<Vec<usize>>) -> Self {
        TensorSig { dtype, dims: dims.into() }
    }

    /// Build from a manifest `IoSpec`-style (shape, dtype-string) pair; an
    /// unknown dtype string is an [`Error::Artifact`] naming the accepted
    /// spellings.
    pub fn from_manifest(shape: &[usize], dtype: &str) -> Result<Self> {
        let dt = SigDType::from_manifest(dtype).ok_or_else(|| {
            Error::Artifact(format!(
                "manifest dtype `{dtype}`? (accepted: f32, i8, u8, i32, i64)"
            ))
        })?;
        Ok(TensorSig::new(dt, shape.to_vec()))
    }

    /// Validate a runtime tensor against this signature (shape + dtype) —
    /// the one-shot check `Runtime::run` applies per argument.
    pub fn check_tensor(&self, t: &Tensor) -> Result<()> {
        if t.dtype() != self.dtype.dtype() || t.shape != self.dims {
            return Err(Error::Shape(format!(
                "arg mismatch: tensor {:?}/{:?} vs spec {}",
                t.shape,
                t.dtype(),
                self.render()
            )));
        }
        Ok(())
    }

    /// Compact rendering (`f32[8,128]`), used in diagnostics.
    pub fn render(&self) -> String {
        let dims =
            self.dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",");
        format!("{}[{dims}]", self.dtype.as_manifest())
    }
}

/// The ENTRY signature of one HLO module: parameter signatures in index
/// order and result signatures (the AOT side always lowers with
/// `return_tuple=True`, so a single-output graph still has one result).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HloSignature {
    pub params: Vec<TensorSig>,
    pub results: Vec<TensorSig>,
}

/// Parse one shape token (`f32[8,128]{1,0}`, `s32[8]`, `f32[]`) into a
/// [`TensorSig`]; the layout suffix is ignored.
fn parse_shape_token(tok: &str) -> Result<TensorSig> {
    let tok = tok.trim();
    let open = tok
        .find('[')
        .ok_or_else(|| Error::Artifact(format!("hlo: shape token `{tok}` has no `[`")))?;
    let close = tok[open..]
        .find(']')
        .map(|i| open + i)
        .ok_or_else(|| Error::Artifact(format!("hlo: shape token `{tok}` has no `]`")))?;
    let dtype = SigDType::from_hlo(&tok[..open]).ok_or_else(|| {
        Error::Artifact(format!("hlo: unsupported element type in `{tok}`"))
    })?;
    let mut dims = Vec::new();
    for d in tok[open + 1..close].split(',').filter(|d| !d.trim().is_empty()) {
        dims.push(d.trim().parse::<usize>().map_err(|_| {
            Error::Artifact(format!("hlo: non-numeric dim in shape token `{tok}`"))
        })?);
    }
    Ok(TensorSig::new(dtype, dims))
}

/// Split a `(a, b, (c, d))`-style list body at depth-0 commas.
fn split_top_level(body: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let (mut depth, mut start) = (0usize, 0usize);
    for (i, c) in body.char_indices() {
        match c {
            '(' | '{' | '[' => depth += 1,
            ')' | '}' | ']' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                out.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if !body[start..].trim().is_empty() {
        out.push(&body[start..]);
    }
    out
}

/// Parse one side of the layout arrow: either a paren-wrapped list of shape
/// tokens or a single bare shape.  Nested tuple entries are rejected — the
/// AOT exporter lowers with `use_tuple_args=False`, so a tuple *parameter*
/// means the file did not come from our exporter.
fn parse_side(side: &str) -> Result<Vec<TensorSig>> {
    let side = side.trim();
    let inner = match side.strip_prefix('(') {
        Some(rest) => rest.strip_suffix(')').ok_or_else(|| {
            Error::Artifact("hlo: unbalanced parens in entry layout".into())
        })?,
        None => return Ok(vec![parse_shape_token(side)?]),
    };
    let mut out = Vec::new();
    for tok in split_top_level(inner) {
        let tok = tok.trim();
        if tok.starts_with('(') {
            return Err(Error::Artifact(
                "hlo: nested tuple in entry signature (the exporter lowers \
                 with use_tuple_args=False)"
                    .into(),
            ));
        }
        out.push(parse_shape_token(tok)?);
    }
    Ok(out)
}

/// Extract a balanced `{...}` body starting at `text[start]` (which must be
/// `{`); returns the inside.
fn balanced_braces(text: &str, start: usize) -> Result<&str> {
    let bytes = text.as_bytes();
    if bytes.get(start) != Some(&b'{') {
        return Err(Error::Artifact("hlo: entry_computation_layout has no `{`".into()));
    }
    let mut depth = 0usize;
    for (i, c) in text[start..].char_indices() {
        match c {
            '{' => depth += 1,
            '}' => {
                depth -= 1;
                if depth == 0 {
                    return Ok(&text[start + 1..start + i]);
                }
            }
            _ => {}
        }
    }
    Err(Error::Artifact("hlo: unterminated entry_computation_layout".into()))
}

/// Split the layout body at the depth-0 `->` arrow.
fn split_arrow(body: &str) -> Result<(&str, &str)> {
    let bytes = body.as_bytes();
    let mut depth = 0usize;
    for i in 0..bytes.len() {
        match bytes[i] {
            b'(' | b'{' | b'[' => depth += 1,
            b')' | b'}' | b']' => depth = depth.saturating_sub(1),
            b'-' if depth == 0 && bytes.get(i + 1) == Some(&b'>') => {
                return Ok((&body[..i], &body[i + 2..]));
            }
            _ => {}
        }
    }
    Err(Error::Artifact("hlo: entry layout has no `->` arrow".into()))
}

/// Fallback for files missing the layout header: scan the text for
/// `parameter(N)` declarations and the `ROOT` instruction's shape.
fn parse_entry_body(text: &str) -> Result<HloSignature> {
    let mut params: Vec<(usize, TensorSig)> = Vec::new();
    let mut results: Option<Vec<TensorSig>> = None;
    for line in text.lines() {
        let line = line.trim();
        let Some(eq) = line.find(" = ") else { continue };
        let rhs = &line[eq + 3..];
        if let Some(ppos) = rhs.find("parameter(") {
            let idx_body = &rhs[ppos + "parameter(".len()..];
            let idx: usize = idx_body
                .split(')')
                .next()
                .and_then(|s| s.trim().parse().ok())
                .ok_or_else(|| Error::Artifact("hlo: bad parameter index".into()))?;
            let shape_tok = rhs.split_whitespace().next().unwrap_or("");
            params.push((idx, parse_shape_token(shape_tok)?));
        } else if line.starts_with("ROOT ") {
            let shape_str = if rhs.trim_start().starts_with('(') {
                let open = rhs.find('(').unwrap_or(0);
                let mut depth = 0usize;
                let mut end = rhs.len();
                for (i, c) in rhs[open..].char_indices() {
                    match c {
                        '(' => depth += 1,
                        ')' => {
                            depth -= 1;
                            if depth == 0 {
                                end = open + i + 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                }
                &rhs[open..end]
            } else {
                rhs.split_whitespace().next().unwrap_or("")
            };
            results = Some(parse_side(shape_str)?);
        }
    }
    let results = results
        .ok_or_else(|| Error::Artifact("hlo: no ROOT instruction found".into()))?;
    if params.is_empty() {
        return Err(Error::Artifact("hlo: no parameter declarations found".into()));
    }
    params.sort_by_key(|(i, _)| *i);
    for (want, (got, _)) in params.iter().enumerate() {
        if *got != want {
            return Err(Error::Artifact(format!(
                "hlo: parameter indices not contiguous (missing {want})"
            )));
        }
    }
    Ok(HloSignature { params: params.into_iter().map(|(_, s)| s).collect(), results })
}

/// Parse the ENTRY signature out of HLO text.  Primary source is the
/// `entry_computation_layout={(...)->(...)}` header every
/// `as_hlo_text()` dump carries; files that lost the header fall back to a
/// scan of the ENTRY body (`parameter(N)` declarations + the ROOT shape).
/// Empty or signature-free text is an [`Error::Artifact`].
pub fn parse_signature(text: &str) -> Result<HloSignature> {
    if text.trim().is_empty() {
        return Err(Error::Artifact("hlo: empty file".into()));
    }
    if let Some(pos) = text.find("entry_computation_layout=") {
        let body = balanced_braces(text, pos + "entry_computation_layout=".len())?;
        let (lhs, rhs) = split_arrow(body)?;
        return Ok(HloSignature { params: parse_side(lhs)?, results: parse_side(rhs)? });
    }
    parse_entry_body(text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f32s(dims: &[usize]) -> TensorSig {
        TensorSig::new(SigDType::F32, dims.to_vec())
    }

    #[test]
    fn parses_layout_header() {
        let text = "HloModule jit_f, entry_computation_layout=\
                    {(s32[8,128]{1,0}, f32[2048,128]{1,0}, f32[128,128]{1,0})\
                    ->(f32[8,128,128]{2,1,0})}\n\nENTRY main.7 {\n}\n";
        let sig = parse_signature(text).unwrap();
        assert_eq!(sig.params.len(), 3);
        assert_eq!(sig.params[0], TensorSig::new(SigDType::I32, vec![8, 128]));
        assert_eq!(sig.params[1], f32s(&[2048, 128]));
        assert_eq!(sig.results, vec![f32s(&[8, 128, 128])]);
    }

    #[test]
    fn parses_multi_result_and_scalars() {
        let text = "HloModule jit_g, entry_computation_layout=\
                    {(f32[4,8]{1,0}, f32[])->(f32[4,8]{1,0}, f32[4]{0}, f32[1]{0})}";
        let sig = parse_signature(text).unwrap();
        assert_eq!(sig.params[1], f32s(&[]));
        assert_eq!(
            sig.results,
            vec![f32s(&[4, 8]), f32s(&[4]), f32s(&[1])]
        );
    }

    #[test]
    fn falls_back_to_entry_body() {
        let text = "HloModule lost_header\n\nENTRY main.5 {\n  \
                    Arg_1.2 = f32[16]{0} parameter(1)\n  \
                    Arg_0.1 = s32[8,128]{1,0} parameter(0)\n  \
                    ROOT tuple.4 = (f32[8,128]{1,0}, f32[1]{0}) tuple(x, y)\n}\n";
        let sig = parse_signature(text).unwrap();
        assert_eq!(sig.params[0].dtype, SigDType::I32);
        assert_eq!(sig.params[1], f32s(&[16]));
        assert_eq!(sig.results, vec![f32s(&[8, 128]), f32s(&[1])]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_signature("").is_err());
        assert!(parse_signature("   \n").is_err());
        assert!(parse_signature("; just a comment\n").is_err());
        assert!(parse_signature("entry_computation_layout={(f32[8)->(}").is_err());
        // unsupported element type
        assert!(parse_signature(
            "HloModule m, entry_computation_layout={(c64[8]{0})->(f32[8]{0})}"
        )
        .is_err());
        // nested tuple parameter
        assert!(parse_signature(
            "HloModule m, entry_computation_layout=\
             {((f32[8]{0}, f32[8]{0}))->(f32[8]{0})}"
        )
        .is_err());
    }

    #[test]
    fn sig_dtype_maps_both_spellings() {
        for (m, h) in [("f32", "f32"), ("i8", "s8"), ("u8", "u8"), ("i32", "s32"),
                       ("i64", "s64")] {
            let a = SigDType::from_manifest(m).unwrap();
            let b = SigDType::from_hlo(h).unwrap();
            assert_eq!(a, b);
            assert_eq!(a.as_manifest(), m);
        }
        assert!(SigDType::from_manifest("f16").is_none());
        assert!(SigDType::from_hlo("pred").is_none());
    }

    #[test]
    fn check_tensor_via_sig() {
        let t = Tensor::zeros(&[2, 2]);
        TensorSig::from_manifest(&[2, 2], "f32").unwrap().check_tensor(&t).unwrap();
        assert!(TensorSig::from_manifest(&[2, 2], "i8")
            .unwrap()
            .check_tensor(&t)
            .is_err());
        assert!(TensorSig::from_manifest(&[4], "f32")
            .unwrap()
            .check_tensor(&t)
            .is_err());
        assert!(TensorSig::from_manifest(&[2, 2], "f16").is_err());
        assert_eq!(f32s(&[8, 128]).render(), "f32[8,128]");
    }
}

//! NT01xx — `manifest.json` schema & consistency (the `manifest` lint).
//!
//! A diagnostics-collecting re-implementation of the strict
//! `ArtifactManifest::load` walk: where the loader fail-fasts on the first
//! `Error::Artifact`, this rule keeps walking the raw JSON and reports
//! *every* violation with its JSON path, plus two checks the loader cannot
//! express — graph HLO files actually present on disk (NT0108) and
//! duplicate `(model, graph)` entries that the lookup index would silently
//! collapse (NT0109).

use std::collections::BTreeSet;

use crate::util::json::Json;

use super::codes;
use super::diagnostics::{Diagnostic, Report};
use super::{CheckContext, Lint};

pub struct ManifestLint;

/// NT0103: a required key is missing or has the wrong type.
fn key_diag(origin: &str, field: &str, msg: String) -> Diagnostic {
    Diagnostic::error(codes::MANIFEST_KEY, msg)
        .at(origin)
        .field(field)
        .fix("re-run the AOT export (`make artifacts`)")
}

fn get_usize(root: &Json, key: &str, origin: &str, report: &mut Report) -> Option<usize> {
    match root.get(key) {
        None => {
            report.push(key_diag(origin, key, format!("manifest: missing key `{key}`")));
            None
        }
        Some(v) => match v.as_usize() {
            Some(u) => Some(u),
            None => {
                report.push(key_diag(origin, key, format!("manifest: `{key}` not a number")));
                None
            }
        },
    }
}

/// Parse a bucket list strictly; `Some` only when every entry is numeric
/// and the list is non-empty (partial lists would shift `bucket_for`).
fn numeric_list(
    v: &Json,
    field: &str,
    code: &'static str,
    origin: &str,
    report: &mut Report,
) -> Option<Vec<usize>> {
    let Some(items) = v.as_arr() else {
        report.push(
            Diagnostic::error(code, format!("manifest: `{field}` not an array"))
                .at(origin)
                .field(field)
                .fix("re-run the AOT export with a numeric bucket list"),
        );
        return None;
    };
    let mut out = Vec::new();
    for it in items {
        match it.as_usize() {
            Some(u) => out.push(u),
            None => {
                report.push(
                    Diagnostic::error(code, format!("manifest: non-numeric entry in `{field}`"))
                        .at(origin)
                        .field(field)
                        .fix("re-run the AOT export with a numeric bucket list"),
                );
                return None;
            }
        }
    }
    if out.is_empty() {
        report.push(
            Diagnostic::error(
                code,
                format!("manifest: empty `{field}` (at least one batch bucket is required)"),
            )
            .at(origin)
            .field(field)
            .fix("re-run the AOT export with at least one bucket"),
        );
        return None;
    }
    Some(out)
}

fn check_groups(root: &Json, origin: &str, report: &mut Report) {
    let Some(g) = root.get("groups") else {
        report.push(key_diag(origin, "groups", "manifest: missing key `groups`".to_string()));
        return;
    };
    let Some(map) = g.as_obj() else {
        report.push(
            Diagnostic::error(codes::MANIFEST_GROUPS, "manifest: `groups` not an object")
                .at(origin)
                .field("groups")
                .fix("re-run the AOT export"),
        );
        return;
    };
    if map.is_empty() {
        report.push(
            Diagnostic::error(
                codes::MANIFEST_GROUPS,
                "manifest: empty `groups` (at least one exported grain is required)",
            )
            .at(origin)
            .field("groups")
            .fix("re-run the AOT export with `--groups`"),
        );
    }
    for (tag, size) in map {
        let field = format!("groups.{tag}");
        let Some(size) = size.as_usize() else {
            report.push(
                Diagnostic::error(
                    codes::MANIFEST_GROUPS,
                    format!("manifest: group `{tag}` not a number"),
                )
                .at(origin)
                .field(field)
                .fix("re-run the AOT export"),
            );
            continue;
        };
        // the tag is derived from the size at lookup time
        // (QuantScheme::group_tag), so a drifted {"g32": 64} would pass
        // grain validation and die at PJRT shape mismatch mid-run
        let expected = if size == 0 { "pc".to_string() } else { format!("g{size}") };
        if *tag != expected {
            report.push(
                Diagnostic::error(
                    codes::MANIFEST_GROUPS,
                    format!(
                        "manifest: group tag `{tag}` inconsistent with size {size} \
                         (expected `{expected}`)"
                    ),
                )
                .at(origin)
                .field(field)
                .fix("re-run the AOT export; grain tags must derive from group sizes"),
            );
        }
    }
}

fn check_decode(
    root: &Json,
    main_buckets: Option<&Vec<usize>>,
    origin: &str,
    report: &mut Report,
) {
    // absent decode = recompute fallback, not an error
    let Some(d) = root.get("decode") else { return };
    let dec_diag = |field: String, msg: String| {
        Diagnostic::error(codes::DECODE_RECORD, msg)
            .at(origin)
            .field(field)
            .fix("re-run the AOT export with the decode graph set")
    };
    let dbuckets = match d.get("buckets") {
        None => {
            report.push(dec_diag(
                "decode.buckets".to_string(),
                "manifest: missing key `decode.buckets`".to_string(),
            ));
            None
        }
        Some(v) => numeric_list(v, "decode.buckets", codes::DECODE_RECORD, origin, report),
    };
    match d.get("caches") {
        None => report.push(dec_diag(
            "decode.caches".to_string(),
            "manifest: missing key `decode.caches`".to_string(),
        )),
        Some(c) => match c.as_obj() {
            None => report.push(dec_diag(
                "decode.caches".to_string(),
                "manifest: `decode.caches` not an object".to_string(),
            )),
            Some(map) => {
                for (name, cache) in map {
                    let base = format!("decode.caches.{name}");
                    if cache.get("n_layer").and_then(|v| v.as_usize()).is_none() {
                        report.push(dec_diag(
                            format!("{base}.n_layer"),
                            format!("decode cache `{name}`: missing or non-numeric `n_layer`"),
                        ));
                    }
                    match cache.get("shape").map(|s| s.as_arr()) {
                        None | Some(None) => report.push(dec_diag(
                            format!("{base}.shape"),
                            format!("decode cache shape of `{name}` missing or not an array"),
                        )),
                        Some(Some(dims)) => {
                            if dims.iter().any(|d| d.as_usize().is_none()) {
                                report.push(dec_diag(
                                    format!("{base}.shape"),
                                    format!(
                                        "manifest: non-numeric dim in decode cache shape \
                                         of `{name}`"
                                    ),
                                ));
                            } else if dims.len() != 3 {
                                report.push(dec_diag(
                                    format!("{base}.shape"),
                                    format!(
                                        "decode cache shape of `{name}` must be \
                                         [n_head, seq, d_head], got {} dims",
                                        dims.len()
                                    ),
                                ));
                            }
                        }
                    }
                }
            }
        },
    }
    // the slot arena is sized by `decode.slots` (defaulting to the largest
    // decode bucket): a value below that bucket cannot hold a full
    // admission round, and a value outside `decode.buckets` has no
    // exported step graph to run full-occupancy decode turns at
    if let Some(s) = d.get("slots") {
        match s.as_usize() {
            None => report.push(dec_diag(
                "decode.slots".to_string(),
                "manifest: `decode.slots` not a number".to_string(),
            )),
            Some(slots) => {
                if let Some(dec) = &dbuckets {
                    let dec_max = dec.iter().copied().max().unwrap_or(0);
                    let arena_diag = |msg: String| {
                        Diagnostic::error(codes::ARENA_SLOTS, msg)
                            .at(origin)
                            .field("decode.slots")
                            .fix(format!(
                                "re-export with `decode.slots` set to a decode \
                                 bucket >= {dec_max}"
                            ))
                    };
                    if slots < dec_max {
                        report.push(arena_diag(format!(
                            "manifest: `decode.slots` = {slots} is smaller than the \
                             largest decode bucket {dec_max} — the KV arena cannot \
                             hold a full admission round"
                        )));
                    } else if !dec.contains(&slots) {
                        let listed =
                            dec.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(", ");
                        report.push(arena_diag(format!(
                            "manifest: `decode.slots` = {slots} has no exported step \
                             graph (decode.buckets: {listed}) — full-occupancy decode \
                             turns cannot dispatch"
                        )));
                    }
                }
            }
        }
    }
    // the scheduler chunks decode steps by the *main* bucket cap: a decode
    // set that cannot fit the largest main bucket fails mid-request
    if let (Some(main), Some(dec)) = (main_buckets, &dbuckets) {
        let main_max = main.iter().copied().max().unwrap_or(0);
        if dec.iter().copied().max().unwrap_or(0) < main_max {
            let listed = dec.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(", ");
            report.push(
                Diagnostic::error(
                    codes::DECODE_BUCKET_GAP,
                    format!(
                        "decode buckets ({listed}) cannot fit the largest exported \
                         batch bucket {main_max} — re-run the AOT export with \
                         matching bucket sets"
                    ),
                )
                .at(origin)
                .field("decode.buckets")
                .fix(format!("re-export with a decode bucket >= {main_max}")),
            );
        }
    }
}

fn check_models(root: &Json, origin: &str, report: &mut Report) {
    let Some(ms) = root.get("models") else {
        report.push(key_diag(origin, "models", "manifest: missing key `models`".to_string()));
        return;
    };
    let Some(map) = ms.as_obj() else {
        report.push(key_diag(origin, "models", "manifest: `models` not an object".to_string()));
        return;
    };
    for (name, m) in map {
        for k in ["n_layer", "d_model", "n_head", "d_ff", "vocab", "seq"] {
            if m.get(k).and_then(|v| v.as_usize()).is_none() {
                report.push(key_diag(
                    origin,
                    &format!("models.{name}.{k}"),
                    format!("manifest: model `{name}`: missing or non-numeric `{k}`"),
                ));
            }
        }
        if m.get("norm").and_then(|v| v.as_str()).is_none() {
            report.push(key_diag(
                origin,
                &format!("models.{name}.norm"),
                format!(
                    "manifest: model `{name}`: missing or non-string `norm` \
                     (accepted: layernorm, rmsnorm)"
                ),
            ));
        }
    }
}

fn check_graphs(root: &Json, dir: &std::path::Path, origin: &str, report: &mut Report) {
    let Some(gs) = root.get("graphs") else {
        report.push(key_diag(origin, "graphs", "manifest: missing key `graphs`".to_string()));
        return;
    };
    let Some(list) = gs.as_arr() else {
        report.push(key_diag(origin, "graphs", "manifest: `graphs` not an array".to_string()));
        return;
    };
    let mut seen = BTreeSet::new();
    for (i, g) in list.iter().enumerate() {
        let gstr = |k: &str| g.get(k).and_then(|v| v.as_str()).map(str::to_string);
        let (model, name, file) = (gstr("model"), gstr("name"), gstr("file"));
        for (k, v) in [("model", &model), ("name", &name), ("file", &file)] {
            if v.is_none() {
                report.push(key_diag(
                    origin,
                    &format!("graphs[{i}].{k}"),
                    format!("manifest: graph entry {i}: missing or non-string `{k}`"),
                ));
            }
        }
        if let (Some(model), Some(name)) = (&model, &name) {
            if !seen.insert((model.clone(), name.clone())) {
                report.push(
                    Diagnostic::error(
                        codes::GRAPH_DUPLICATE,
                        format!(
                            "manifest: duplicate graph entry `{model}.{name}` — the \
                             lookup index would silently keep only the last one"
                        ),
                    )
                    .at(origin)
                    .field(format!("graphs[{i}]"))
                    .fix("re-run the AOT export; each (model, graph) must be unique"),
                );
            }
        }
        if let Some(file) = &file {
            // NT0108 distinguishes *why* the file is unusable — missing vs
            // present-but-empty vs unreadable.  Shallow mode keeps all three
            // a warning; deep mode (`--graphs`) escalates the present-but-
            // broken variants (and garbage content) to NT0501 errors.
            let path = dir.join(file);
            let problem = match std::fs::metadata(&path) {
                Err(_) if !path.exists() => {
                    Some(format!("is missing from {}", dir.display()))
                }
                Err(e) => Some(format!("is unreadable ({e})")),
                Ok(meta) if meta.len() == 0 => Some("exists but is empty".to_string()),
                Ok(_) => None,
            };
            if let Some(problem) = problem {
                report.push(
                    Diagnostic::warn(
                        codes::GRAPH_FILE_MISSING,
                        format!("manifest lists graph file `{file}` but it {problem}"),
                    )
                    .at(origin)
                    .field(format!("graphs[{i}].file"))
                    .fix("re-run `make artifacts` to regenerate the HLO files"),
                );
            }
        }
        match g.get("inputs").map(|v| v.as_arr()) {
            None | Some(None) => report.push(key_diag(
                origin,
                &format!("graphs[{i}].inputs"),
                format!("manifest: graph entry {i}: `inputs` missing or not an array"),
            )),
            Some(Some(items)) => check_io_list(items, i, "inputs", origin, report),
        }
        // `outputs` is optional (pre-signature-recording manifests omit it)
        // but must be well-formed when present
        match g.get("outputs").map(|v| v.as_arr()) {
            None => {}
            Some(None) => report.push(key_diag(
                origin,
                &format!("graphs[{i}].outputs"),
                format!("manifest: graph entry {i}: `outputs` not an array"),
            )),
            Some(Some(items)) => check_io_list(items, i, "outputs", origin, report),
        }
    }
}

/// Shared schema walk for a graph's `inputs` / `outputs` IoSpec lists.
fn check_io_list(items: &[Json], i: usize, what: &str, origin: &str, report: &mut Report) {
    for (j, spec) in items.iter().enumerate() {
        let base = format!("graphs[{i}].{what}[{j}]");
        for k in ["name", "dtype"] {
            if spec.get(k).and_then(|v| v.as_str()).is_none() {
                report.push(key_diag(
                    origin,
                    &format!("{base}.{k}"),
                    format!(
                        "manifest: graph entry {i} {what} {j}: missing or \
                         non-string `{k}`"
                    ),
                ));
            }
        }
        let shape_ok = spec
            .get("shape")
            .and_then(|s| s.as_arr())
            .is_some_and(|dims| dims.iter().all(|d| d.as_usize().is_some()));
        if !shape_ok {
            report.push(key_diag(
                origin,
                &format!("{base}.shape"),
                format!(
                    "manifest: graph entry {i} {what} {j}: `shape` missing \
                     or non-numeric"
                ),
            ));
        }
    }
}

impl Lint for ManifestLint {
    fn name(&self) -> &'static str {
        "manifest"
    }

    fn run(&self, ctx: &CheckContext, report: &mut Report) {
        let Some(dir) = &ctx.manifest_dir else { return };
        let path = dir.join("manifest.json");
        let origin = path.display().to_string();
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) => {
                report.push(
                    Diagnostic::error(
                        codes::MANIFEST_UNREADABLE,
                        format!(
                            "missing manifest.json in {} — run `make artifacts` ({e})",
                            dir.display()
                        ),
                    )
                    .at(origin)
                    .fix("run `make artifacts` to export the AOT graph set"),
                );
                return;
            }
        };
        let root = match Json::parse(&text) {
            Ok(j) => j,
            Err(e) => {
                report.push(
                    Diagnostic::error(codes::MANIFEST_PARSE, format!("manifest: {e}"))
                        .at(origin)
                        .fix("re-run the AOT export; manifest.json is not valid JSON"),
                );
                return;
            }
        };

        if let Some(f) = get_usize(&root, "format", &origin, report) {
            if f != 1 {
                report.push(key_diag(
                    &origin,
                    "format",
                    format!("manifest format != 1 (got {f}; this runtime reads format 1)"),
                ));
            }
        }
        get_usize(&root, "calib_batch", &origin, report);
        let buckets = match root.get("buckets") {
            None => {
                report.push(key_diag(
                    &origin,
                    "buckets",
                    "manifest: missing key `buckets`".to_string(),
                ));
                None
            }
            Some(v) => numeric_list(v, "buckets", codes::MANIFEST_BUCKETS, &origin, report),
        };
        check_groups(&root, &origin, report);
        check_decode(&root, buckets.as_ref(), &origin, report);
        check_models(&root, &origin, report);
        check_graphs(&root, dir, &origin, report);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::run_lints;

    fn ctx_for(name: &str, json: &str) -> CheckContext {
        let dir = std::env::temp_dir().join(format!("nt_manifest_lint_{name}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), json).unwrap();
        CheckContext { manifest_dir: Some(dir), ..CheckContext::default() }
    }

    #[test]
    fn clean_manifest_yields_no_findings() {
        let ctx = ctx_for(
            "clean",
            r#"{"format": 1, "calib_batch": 32, "buckets": [8, 32],
                "groups": {"pc": 0}, "models": {}, "graphs": []}"#,
        );
        let report = run_lints(&ctx);
        assert!(report.is_empty(), "{:?}", report.codes());
    }

    #[test]
    fn collects_every_violation_in_one_run() {
        // missing calib_batch + drifted grain tag + bad decode rank +
        // undersized slot arena + decode bucket gap + duplicate graph:
        // six findings, one pass
        let ctx = ctx_for(
            "multi",
            r#"{"format": 1, "buckets": [8, 32],
                "groups": {"g32": 64},
                "decode": {"buckets": [8], "slots": 4,
                           "caches": {"m": {"n_layer": 2, "shape": [4, 128]}}},
                "models": {},
                "graphs": [
                  {"model": "m", "name": "g", "file": "missing.hlo.txt",
                   "inputs": []},
                  {"model": "m", "name": "g", "file": "missing.hlo.txt",
                   "inputs": []}]}"#,
        );
        let report = run_lints(&ctx);
        let codes = report.codes();
        for want in [
            codes::MANIFEST_KEY,
            codes::MANIFEST_GROUPS,
            codes::DECODE_RECORD,
            codes::ARENA_SLOTS,
            codes::DECODE_BUCKET_GAP,
            codes::GRAPH_DUPLICATE,
            codes::GRAPH_FILE_MISSING,
        ] {
            assert!(codes.contains(&want), "missing {want} in {codes:?}");
        }
    }

    #[test]
    fn decode_slots_arena_compatibility() {
        // slots matching a decode bucket >= the largest is clean
        let ctx = ctx_for(
            "slots_ok",
            r#"{"format": 1, "calib_batch": 32, "buckets": [8],
                "groups": {"pc": 0},
                "decode": {"buckets": [8, 32], "slots": 32, "caches": {}},
                "models": {}, "graphs": []}"#,
        );
        assert!(run_lints(&ctx).is_empty());
        // slots outside decode.buckets has no exported step graph
        let ctx = ctx_for(
            "slots_unexported",
            r#"{"format": 1, "calib_batch": 32, "buckets": [8],
                "groups": {"pc": 0},
                "decode": {"buckets": [8, 32], "slots": 64, "caches": {}},
                "models": {}, "graphs": []}"#,
        );
        let report = run_lints(&ctx);
        assert_eq!(report.codes(), vec![codes::ARENA_SLOTS]);
        assert!(
            report.diagnostics[0].message.contains("no exported step graph"),
            "{}",
            report.diagnostics[0].message
        );
        // a non-numeric slots value is a schema violation, not an arena one
        let ctx = ctx_for(
            "slots_nan",
            r#"{"format": 1, "calib_batch": 32, "buckets": [8],
                "groups": {"pc": 0},
                "decode": {"buckets": [8, 32], "slots": "many", "caches": {}},
                "models": {}, "graphs": []}"#,
        );
        assert_eq!(run_lints(&ctx).codes(), vec![codes::DECODE_RECORD]);
    }

    #[test]
    fn nt0108_distinguishes_missing_and_empty_and_validates_outputs() {
        let ctx = ctx_for(
            "hlo_variants",
            r#"{"format": 1, "calib_batch": 32, "buckets": [8],
                "groups": {"pc": 0}, "models": {},
                "graphs": [
                  {"model": "m", "name": "a.b8", "file": "gone.hlo.txt",
                   "inputs": []},
                  {"model": "m", "name": "b.b8", "file": "empty.hlo.txt",
                   "inputs": [],
                   "outputs": [{"name": "out0", "shape": [8, null],
                                "dtype": "f32"}]}]}"#,
        );
        let dir = ctx.manifest_dir.clone().unwrap();
        std::fs::write(dir.join("empty.hlo.txt"), "").unwrap();
        let report = run_lints(&ctx);
        let missing: Vec<_> = report
            .diagnostics
            .iter()
            .filter(|d| d.code == codes::GRAPH_FILE_MISSING)
            .collect();
        assert_eq!(missing.len(), 2, "{:?}", report.codes());
        assert!(missing[0].message.contains("missing"), "{}", missing[0].message);
        assert!(missing[1].message.contains("empty"), "{}", missing[1].message);
        // the malformed recorded output is a schema violation
        assert!(
            report
                .diagnostics
                .iter()
                .any(|d| d.code == codes::MANIFEST_KEY
                    && d.field.as_deref() == Some("graphs[1].outputs[0].shape")),
            "{:?}",
            report.codes()
        );
    }

    #[test]
    fn unreadable_and_unparsable_short_circuit() {
        let ctx = CheckContext {
            manifest_dir: Some(std::path::PathBuf::from("/definitely/missing")),
            ..CheckContext::default()
        };
        assert_eq!(run_lints(&ctx).codes(), vec![codes::MANIFEST_UNREADABLE]);
        let ctx = ctx_for("garbage", "{not json");
        assert_eq!(run_lints(&ctx).codes(), vec![codes::MANIFEST_PARSE]);
    }
}

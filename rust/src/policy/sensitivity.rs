//! Per-layer quantization sensitivity: trial-quantize each block at every
//! candidate bit width and score the channel-wise output divergence.
//!
//! The measurement deliberately isolates one block at a time: the float
//! stream feeds layer *l* (so upstream quantization error never pollutes the
//! per-layer signal), the block's four linears are quantized through the
//! same `Quantizer` plugin the pipeline will use, and the divergence is the
//! selected tweak-loss distance between `X·W` and `X·Ŵ` over the block's
//! calibration activations. That is exactly the quantity norm tweaking
//! minimizes per layer, which makes the scores commensurable across bit
//! widths and layers.

use std::collections::BTreeMap;
use std::path::Path;

use crate::calib::CalibSet;
use crate::coordinator::FloatModel;
use crate::error::{Error, Result};
use crate::model::{BlockWeights, ModelWeights};
use crate::quant::quantizer::{resolve, LayerContext, Linear, Quantizer, QuantizerParams, LINEARS};
use crate::quant::QuantScheme;
use crate::runtime::Runtime;
use crate::tensor::{matmul, Tensor};
use crate::tweak::loss::{dist_loss, kl_loss, mse_loss};
use crate::tweak::LossKind;
use crate::util::json::{arr, n, obj, s, Json};

/// Default candidate widths: every packed storage width the runtime supports.
pub const DEFAULT_CANDIDATES: [u8; 4] = [2, 3, 4, 8];

/// What to measure: the trial-quantization method, the base scheme (grain
/// source), the candidate bit widths, and the divergence metric.
#[derive(Debug, Clone)]
pub struct SensitivityConfig {
    /// Quantizer spec used for trial quantization (any registered name or
    /// `+`-composition — normally the same method the pipeline will run).
    pub method: String,
    /// Base scheme; candidates inherit its group grain so every emitted
    /// override stays grain-legal.
    pub base: QuantScheme,
    pub candidate_bits: Vec<u8>,
    /// Divergence metric (the tweak-loss distance kernels).
    pub loss: LossKind,
    pub params: QuantizerParams,
}

impl SensitivityConfig {
    pub fn new(method: impl Into<String>, base: QuantScheme) -> Self {
        SensitivityConfig {
            method: method.into(),
            base,
            candidate_bits: DEFAULT_CANDIDATES.to_vec(),
            loss: LossKind::Dist,
            params: QuantizerParams::default(),
        }
    }

    /// Candidates sorted, deduplicated, and checked against the packed
    /// storage widths; empty or unpackable candidate lists are rejected.
    pub fn normalized_candidates(&self) -> Result<Vec<u8>> {
        let mut c = self.candidate_bits.clone();
        c.sort_unstable();
        c.dedup();
        if c.is_empty() {
            return Err(Error::Config("no candidate bit widths to profile".into()));
        }
        for &bits in &c {
            QuantScheme { bits, group_size: self.base.group_size }.pack_bits()?;
        }
        Ok(c)
    }
}

/// One layer's divergence at each candidate bit width.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSensitivity {
    pub layer: usize,
    /// candidate bit width → summed divergence of the four linear outputs
    pub scores: BTreeMap<u8, f32>,
}

impl LayerSensitivity {
    pub fn score(&self, bits: u8) -> Option<f32> {
        self.scores.get(&bits).copied()
    }
}

/// The measured profile plus full provenance — everything the planner (and
/// a reader of `sensitivity.json`) needs to trust or reproduce it.
#[derive(Debug, Clone, PartialEq)]
pub struct SensitivityProfile {
    pub model: String,
    /// canonical quantizer spec the trials ran with
    pub method: String,
    /// base grain tag (`pc`, `g64`, ...) every candidate shared
    pub group_tag: String,
    pub calib_source: String,
    /// divergence metric name (`dist` | `mse` | `kl`)
    pub loss: String,
    pub candidate_bits: Vec<u8>,
    pub layers: Vec<LayerSensitivity>,
    /// FNV-1a hex of the float checkpoint the profile was measured against
    /// (`weights_<model>.ntz` bytes at profile time). `None` on profiles
    /// persisted before the field existed; when present, planners reject a
    /// profile whose checkpoint has since been re-exported (NT0311) instead
    /// of silently allocating on stale scores.
    pub ckpt_hash: Option<String>,
}

impl SensitivityProfile {
    /// One-line provenance string echoed into plans, metrics, and reports.
    pub fn provenance(&self) -> String {
        format!(
            "model={} method={} grain={} calib={} loss={}",
            self.model, self.method, self.group_tag, self.calib_source, self.loss
        )
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", s(self.model.clone())),
            ("method", s(self.method.clone())),
            ("group_tag", s(self.group_tag.clone())),
            ("calib_source", s(self.calib_source.clone())),
            ("loss", s(self.loss.clone())),
            (
                "candidate_bits",
                arr(self.candidate_bits.iter().map(|&b| n(b as f64)).collect()),
            ),
            (
                "layers",
                arr(self
                    .layers
                    .iter()
                    .map(|l| {
                        let scores = l
                            .scores
                            .iter()
                            .map(|(b, v)| (b.to_string(), n(*v as f64)))
                            .collect();
                        obj(vec![
                            ("layer", n(l.layer as f64)),
                            ("scores", Json::Obj(scores)),
                        ])
                    })
                    .collect()),
            ),
        ];
        if let Some(h) = &self.ckpt_hash {
            fields.push(("ckpt_hash", s(h.clone())));
        }
        obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let get = |k: &str| {
            j.get(k)
                .ok_or_else(|| Error::Json(format!("sensitivity profile: missing `{k}`")))
        };
        let get_str = |k: &str| -> Result<String> {
            get(k)?
                .as_str()
                .map(String::from)
                .ok_or_else(|| Error::Json(format!("sensitivity profile: `{k}` must be a string")))
        };
        let candidate_bits = get("candidate_bits")?
            .as_arr()
            .ok_or_else(|| Error::Json("sensitivity profile: `candidate_bits` must be an array".into()))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .filter(|&b| b > 0 && b <= u8::MAX as usize)
                    .map(|b| b as u8)
                    .ok_or_else(|| Error::Json("sensitivity profile: bad candidate bit width".into()))
            })
            .collect::<Result<Vec<u8>>>()?;
        let mut layers = Vec::new();
        for lj in get("layers")?
            .as_arr()
            .ok_or_else(|| Error::Json("sensitivity profile: `layers` must be an array".into()))?
        {
            let layer = lj
                .get("layer")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| Error::Json("sensitivity profile: layer entry missing `layer`".into()))?;
            let raw = lj
                .get("scores")
                .and_then(|v| v.as_obj())
                .ok_or_else(|| Error::Json(format!("sensitivity profile: layer {layer} missing `scores`")))?;
            let mut scores = BTreeMap::new();
            for (k, v) in raw {
                let bits: u8 = k.parse().map_err(|_| {
                    Error::Json(format!("sensitivity profile: layer {layer}: bad bit key `{k}`"))
                })?;
                let score = v.as_f64().ok_or_else(|| {
                    Error::Json(format!("sensitivity profile: layer {layer}: score `{k}` not a number"))
                })?;
                scores.insert(bits, score as f32);
            }
            layers.push(LayerSensitivity { layer, scores });
        }
        // optional: absent on profiles persisted before provenance hardening
        let ckpt_hash = match j.get("ckpt_hash") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .map(String::from)
                    .ok_or_else(|| {
                        Error::Json("sensitivity profile: `ckpt_hash` must be a string".into())
                    })?,
            ),
        };
        Ok(SensitivityProfile {
            model: get_str("model")?,
            method: get_str("method")?,
            group_tag: get_str("group_tag")?,
            calib_source: get_str("calib_source")?,
            loss: get_str("loss")?,
            candidate_bits,
            layers,
            ckpt_hash,
        })
    }

    /// Persist as `sensitivity.json` (creating parent directories).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().emit())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// Divergence of one block quantized at `scheme`, scored on static taps —
/// the offline core behind [`SensitivityProfiler`]. Taps are one activation
/// tensor per linear in tap order (any rank; flattened to `[rows, K]`),
/// Hessian-needing methods fall back to CPU Gram matrices, so no runtime or
/// AOT artifacts are involved.
pub fn score_layer(
    weights: BlockWeights<'_>,
    taps: &[Tensor],
    scheme: QuantScheme,
    quantizer: &dyn Quantizer,
    loss: LossKind,
) -> Result<f32> {
    let mut ctx = LayerContext::with_static_taps(weights, taps.to_vec(), scheme);
    let bq = quantizer.quantize_layer(&mut ctx)?;
    let mut total = 0.0f32;
    for lin in LINEARS {
        // scale-corrected tap: consistent with the (possibly preprocessed)
        // effective weight, so fold-based methods are scored fairly
        let x = ctx.tap(lin)?;
        let y_f = matmul(&x, ctx.weight(lin))?;
        let qw = match lin {
            Linear::Qkv => &bq.qkv,
            Linear::Proj => &bq.proj,
            Linear::Fc1 => &bq.fc1,
            Linear::Fc2 => &bq.fc2,
        };
        let deq = Tensor::f32(&[qw.k, qw.n], qw.dequantize());
        let y_q = matmul(&x, &deq)?;
        total += match loss {
            LossKind::Dist => dist_loss(&y_f, &y_q)?,
            LossKind::Mse => mse_loss(&y_f, &y_q)?,
            LossKind::Kl => kl_loss(&y_f, &y_q)?,
        };
    }
    Ok(total)
}

/// Runs the calibration set through the float model and measures every
/// (layer, candidate bit width) pair. The float stream advances through the
/// float block graphs; each layer's taps are fetched once and reused across
/// candidates.
pub struct SensitivityProfiler<'rt, 'w> {
    runtime: &'rt Runtime,
    weights: &'w ModelWeights,
    cfg: SensitivityConfig,
}

impl<'rt, 'w> SensitivityProfiler<'rt, 'w> {
    pub fn new(runtime: &'rt Runtime, weights: &'w ModelWeights, cfg: SensitivityConfig) -> Self {
        SensitivityProfiler { runtime, weights, cfg }
    }

    /// Measure the full profile over `calib` (which must match the exported
    /// calibration batch, like the pipeline).
    pub fn profile(&self, calib: &CalibSet) -> Result<SensitivityProfile> {
        let candidates = self.cfg.normalized_candidates()?;
        let cb = self.runtime.manifest.calib_batch;
        if calib.n_samples() != cb {
            return Err(Error::msg(format!(
                "calibration set has {} samples; profiling graphs need {cb}",
                calib.n_samples()
            )));
        }
        let quantizer: Box<dyn Quantizer> = resolve(&self.cfg.method, &self.cfg.params)?;
        let fm = FloatModel::new(self.runtime, self.weights)?;
        let mcfg = &self.weights.config;
        let trace = self.runtime.trace().map(|t| (t.clone(), t.track("policy")));
        let mut x = fm.embed(&calib.tokens)?;
        let mut layers = Vec::with_capacity(mcfg.n_layer);
        for layer in 0..mcfg.n_layer {
            let ts = trace.as_ref().map(|(t, _)| t.now());
            let taps = fm.block_taps(layer, &x)?;
            let bw = self.weights.block(layer)?;
            let mut scores = BTreeMap::new();
            // each candidate gets a fresh context (taps + float reference
            // recomputed): preprocessing may be width-dependent — AWQ grid-
            // searches its scales against quantization at the target width —
            // so the effective weights the float side must be compared
            // against can differ per candidate
            for &bits in &candidates {
                let scheme = QuantScheme { bits, group_size: self.cfg.base.group_size };
                let score =
                    score_layer(bw, &taps, scheme, quantizer.as_ref(), self.cfg.loss)?;
                scores.insert(bits, score);
            }
            if let Some((t, tid)) = &trace {
                t.complete(
                    *tid,
                    "score_layer",
                    ts.unwrap_or(0),
                    vec![("layer", crate::util::json::n(layer as f64))],
                );
            }
            if crate::obs::log::enabled(crate::obs::Level::Info) {
                let summary = scores
                    .iter()
                    .map(|(b, v)| format!("{b}b={v:.5}"))
                    .collect::<Vec<_>>()
                    .join(" ");
                crate::log_info!("policy", "layer {layer}: {summary}");
            }
            layers.push(LayerSensitivity { layer, scores });
            x = fm.block_fwd(layer, &x)?;
        }
        Ok(SensitivityProfile {
            model: mcfg.name.clone(),
            method: quantizer.name().to_string(),
            group_tag: self.cfg.base.group_tag(),
            calib_source: calib.source.clone(),
            loss: self.cfg.loss.as_str().to_string(),
            candidate_bits: candidates,
            layers,
            // the profiler sees tensors, not the file: callers that know the
            // checkpoint path stamp the hash before persisting (the CLI does)
            ckpt_hash: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile_fixture() -> SensitivityProfile {
        SensitivityProfile {
            model: "nt-tiny".into(),
            method: "gptq".into(),
            group_tag: "g64".into(),
            calib_source: "gen-v2".into(),
            loss: "dist".into(),
            candidate_bits: vec![2, 4],
            layers: vec![
                LayerSensitivity {
                    layer: 0,
                    scores: BTreeMap::from([(2u8, 1.5f32), (4u8, 0.25f32)]),
                },
                LayerSensitivity {
                    layer: 1,
                    scores: BTreeMap::from([(2u8, 0.75f32), (4u8, 0.125f32)]),
                },
            ],
            ckpt_hash: Some("cbf29ce484222325".into()),
        }
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let p = profile_fixture();
        let back = SensitivityProfile::from_json(&Json::parse(&p.to_json().emit()).unwrap())
            .unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn ckpt_hash_is_optional_for_old_profiles() {
        // a pre-hardening profile (no ckpt_hash key) still loads, with None
        let legacy = r#"{"model":"m","method":"rtn","group_tag":"pc",
            "calib_source":"gen-v2","loss":"dist","candidate_bits":[2],
            "layers":[{"layer":0,"scores":{"2":1.0}}]}"#;
        let p = SensitivityProfile::from_json(&Json::parse(legacy).unwrap()).unwrap();
        assert_eq!(p.ckpt_hash, None);
        // and re-emitting it does not invent the key
        assert!(!p.to_json().emit().contains("ckpt_hash"));
        // a mistyped hash is rejected, not coerced
        let bad = legacy.replace(
            "\"candidate_bits\"",
            "\"ckpt_hash\":7,\"candidate_bits\"",
        );
        assert!(SensitivityProfile::from_json(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn from_json_rejects_malformed() {
        assert!(SensitivityProfile::from_json(&Json::parse("{}").unwrap()).is_err());
        let no_scores = r#"{"model":"m","method":"rtn","group_tag":"pc",
            "calib_source":"gen-v2","loss":"dist","candidate_bits":[2],
            "layers":[{"layer":0}]}"#;
        assert!(SensitivityProfile::from_json(&Json::parse(no_scores).unwrap()).is_err());
        let bad_key = r#"{"model":"m","method":"rtn","group_tag":"pc",
            "calib_source":"gen-v2","loss":"dist","candidate_bits":[2],
            "layers":[{"layer":0,"scores":{"two":1.0}}]}"#;
        assert!(SensitivityProfile::from_json(&Json::parse(bad_key).unwrap()).is_err());
    }

    #[test]
    fn provenance_names_every_input() {
        let p = profile_fixture().provenance();
        for part in ["nt-tiny", "gptq", "g64", "gen-v2", "dist"] {
            assert!(p.contains(part), "{p} missing {part}");
        }
    }

    #[test]
    fn candidates_normalize_and_reject() {
        let mut cfg = SensitivityConfig::new("rtn", QuantScheme::w2_g64());
        cfg.candidate_bits = vec![8, 2, 4, 2];
        assert_eq!(cfg.normalized_candidates().unwrap(), vec![2, 4, 8]);
        cfg.candidate_bits = vec![];
        assert!(cfg.normalized_candidates().is_err());
        cfg.candidate_bits = vec![2, 5]; // no packed storage for 5-bit
        assert!(cfg.normalized_candidates().is_err());
    }
}

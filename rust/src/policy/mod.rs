//! Sensitivity-driven mixed-precision policy: measure where the model is
//! fragile, then spend the bit budget there automatically.
//!
//! The paper's 2-bit results depend on per-layer bit allocation, and the
//! pipeline has supported per-layer overrides since the plugin API landed
//! (`PipelineConfig::layer_schemes` / `--layer-bits`) — but every override
//! was hand-typed. This subsystem closes that loop in two stages:
//!
//! 1. [`SensitivityProfiler`] runs the calibration set through the float
//!    model (reusing the `FloatModel` activation taps the pipeline already
//!    exports per block), quantizes each transformer block in isolation at
//!    every candidate bit width through the open `Quantizer` registry, and
//!    scores the channel-wise divergence of the four linear outputs with
//!    the tweak-loss distance kernels (Dist / Mse / Kl, selectable). The
//!    result is a [`SensitivityProfile`] — a per-layer, per-bit-width
//!    divergence table with full provenance (model, method, grain,
//!    calibration source, loss) — persisted as `sensitivity.json` so
//!    planning is re-runnable without re-profiling.
//! 2. [`BitBudgetPlanner`] solves a greedy marginal-gain-per-bit
//!    allocation under an *average-bits* budget (`--target-bits 2.25`):
//!    every layer starts at the smallest candidate width, and the planner
//!    repeatedly upgrades the layer with the highest measured divergence
//!    reduction per extra bit until the budget is exhausted. The emitted
//!    [`BitPlan`] is a `BTreeMap<usize, QuantScheme>` that drops straight
//!    into `PipelineConfig::layer_schemes`; all schemes share the base
//!    scheme's group grain, so plan legality is exactly the existing
//!    mixed-precision validation.
//!
//! CLI surface: `normtweak plan --target-bits B` (profile + plan + print),
//! `normtweak quantize --auto-bits B` (plan feeds the pipeline directly).
//! The scoring core ([`score_layer`]) runs on static taps with CPU Gram
//! matrices, so the whole profiler/planner suite is testable offline — no
//! AOT artifacts required.

mod planner;
mod sensitivity;

pub use planner::{BitBudgetPlanner, BitPlan, PLAN_SCHEMA};
pub use sensitivity::{
    score_layer, LayerSensitivity, SensitivityConfig, SensitivityProfile, SensitivityProfiler,
    DEFAULT_CANDIDATES,
};

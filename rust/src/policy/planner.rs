//! Bit-budget allocation: turn a sensitivity profile into per-layer scheme
//! overrides under an average-bits budget.
//!
//! Greedy marginal-gain knapsack: every layer starts at the smallest
//! candidate width; each round upgrades the layer whose next step up buys
//! the largest measured divergence reduction per extra bit, as long as the
//! total still fits `target_bits × n_layers`. Ties break toward the
//! earliest layer, and zero-gain upgrades are never taken, so the
//! allocation is deterministic and the mean allocated width never exceeds
//! the budget.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::quant::QuantScheme;
use crate::util::json::{n, obj, Json};

use super::sensitivity::SensitivityProfile;

/// Allocates a [`SensitivityProfile`] under an average-bits budget.
#[derive(Debug, Clone, Copy)]
pub struct BitBudgetPlanner {
    /// Base scheme: provides the group grain every override shares (the
    /// forward graphs are compiled per grain) and must match the profile's.
    pub base: QuantScheme,
    /// Budget as *mean bits per layer* (e.g. 2.25), not a per-layer cap.
    pub target_bits: f32,
}

/// The planner's output: per-layer schemes ready for
/// `PipelineConfig::layer_schemes`, plus the audit trail.
#[derive(Debug, Clone, PartialEq)]
pub struct BitPlan {
    pub schemes: BTreeMap<usize, QuantScheme>,
    /// mean allocated width — guaranteed ≤ `target_bits`
    pub mean_bits: f32,
    pub target_bits: f32,
    /// provenance of the profile this plan came from
    pub provenance: String,
}

impl BitPlan {
    /// The equivalent `--layer-bits` value (`"0:4,1:2,..."`).
    pub fn layer_bits_string(&self) -> String {
        self.schemes
            .iter()
            .map(|(l, s)| format!("{l}:{}", s.bits))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// The machine-readable allocation — one schema shared by
    /// `normtweak plan --format json` stdout and the `plan` section of a
    /// search recipe artifact, so external tooling parses one shape.
    /// `layers` maps layer index to `{bits, group}` (`group` null =
    /// per-channel).
    pub fn to_json(&self) -> Json {
        let layers: BTreeMap<String, Json> = self
            .schemes
            .iter()
            .map(|(l, s)| {
                (
                    l.to_string(),
                    obj(vec![
                        ("bits", n(f64::from(s.bits))),
                        ("group", s.group_size.map_or(Json::Null, |g| n(g as f64))),
                    ]),
                )
            })
            .collect();
        obj(vec![
            ("schema", crate::util::json::s(PLAN_SCHEMA)),
            ("target_bits", n(f64::from(self.target_bits))),
            ("mean_bits", n(f64::from(self.mean_bits))),
            ("provenance", crate::util::json::s(self.provenance.clone())),
            ("layers", Json::Obj(layers)),
        ])
    }

    /// Inverse of [`BitPlan::to_json`]; rejects unknown schemas and
    /// malformed layer entries so a hand-edited recipe fails loudly.
    pub fn from_json(j: &Json) -> Result<Self> {
        let bad = |m: &str| Error::Json(format!("bit plan: {m}"));
        match j.get("schema").and_then(|v| v.as_str()) {
            Some(PLAN_SCHEMA) => {}
            other => {
                return Err(bad(&format!(
                    "schema `{}` (expected `{PLAN_SCHEMA}`)",
                    other.unwrap_or("<missing>")
                )))
            }
        }
        let target_bits = j
            .get("target_bits")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| bad("missing `target_bits`"))? as f32;
        let mean_bits = j
            .get("mean_bits")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| bad("missing `mean_bits`"))? as f32;
        let provenance = j
            .get("provenance")
            .and_then(|v| v.as_str())
            .ok_or_else(|| bad("missing `provenance`"))?
            .to_string();
        let raw = j
            .get("layers")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| bad("missing `layers` object"))?;
        let mut schemes = BTreeMap::new();
        for (k, v) in raw {
            let layer: usize = k
                .parse()
                .map_err(|_| bad(&format!("bad layer key `{k}`")))?;
            let bits = v
                .get("bits")
                .and_then(|b| b.as_usize())
                .filter(|&b| b > 0 && b <= u8::MAX as usize)
                .ok_or_else(|| bad(&format!("layer {layer}: bad `bits`")))?
                as u8;
            let group_size = match v.get("group") {
                None | Some(Json::Null) => None,
                Some(g) => Some(
                    g.as_usize()
                        .ok_or_else(|| bad(&format!("layer {layer}: bad `group`")))?,
                ),
            };
            schemes.insert(layer, QuantScheme { bits, group_size });
        }
        Ok(BitPlan { schemes, mean_bits, target_bits, provenance })
    }
}

/// Schema tag for [`BitPlan::to_json`].
pub const PLAN_SCHEMA: &str = "normtweak.plan.v1";

impl BitBudgetPlanner {
    pub fn new(base: QuantScheme, target_bits: f32) -> Self {
        BitBudgetPlanner { base, target_bits }
    }

    pub fn plan(&self, profile: &SensitivityProfile) -> Result<BitPlan> {
        let base_tag = self.base.group_tag();
        if profile.group_tag != base_tag {
            return Err(Error::Config(format!(
                "sensitivity profile was measured at grain `{}` but the base scheme is \
                 `{base_tag}`; re-profile at the deployment grain",
                profile.group_tag
            )));
        }
        let n = profile.layers.len();
        if n == 0 {
            return Err(Error::Config("sensitivity profile has no layers".into()));
        }
        let mut cands = profile.candidate_bits.clone();
        cands.sort_unstable();
        cands.dedup();
        if cands.is_empty() {
            return Err(Error::Config("sensitivity profile has no candidate bit widths".into()));
        }
        for &bits in &cands {
            QuantScheme { bits, group_size: self.base.group_size }.pack_bits()?;
        }
        let min_bits = cands[0];
        if self.target_bits + 1e-6 < min_bits as f32 {
            return Err(Error::Config(format!(
                "target of {:.2} average bits is below the smallest candidate width \
                 {min_bits} (candidates: {cands:?}) — infeasible budget",
                self.target_bits
            )));
        }
        let mut seen = std::collections::BTreeSet::new();
        for l in &profile.layers {
            if !seen.insert(l.layer) {
                return Err(Error::Config(format!(
                    "sensitivity profile lists layer {} twice",
                    l.layer
                )));
            }
            for &bits in &cands {
                if l.score(bits).is_none() {
                    return Err(Error::Config(format!(
                        "layer {} has no sensitivity score at {bits} bits; re-profile \
                         with the full candidate set",
                        l.layer
                    )));
                }
            }
        }

        // greedy upgrades from the floor allocation
        let mut idx = vec![0usize; n]; // per-layer index into `cands`
        let mut total_bits = min_bits as f64 * n as f64;
        let budget = self.target_bits as f64 * n as f64 + 1e-6;
        loop {
            let mut best: Option<(usize, f64)> = None;
            for (pos, l) in profile.layers.iter().enumerate() {
                if idx[pos] + 1 >= cands.len() {
                    continue;
                }
                let cur = cands[idx[pos]];
                let next = cands[idx[pos] + 1];
                let cost = f64::from(next - cur);
                if total_bits + cost > budget {
                    continue;
                }
                // score coverage was validated before the loop; a layer
                // that still lacks one simply never gets promoted
                let (Some(sc), Some(sn)) = (l.score(cur), l.score(next)) else {
                    continue;
                };
                let gain = f64::from(sc - sn);
                if gain <= 0.0 {
                    continue; // spending bits with no measured benefit
                }
                let ratio = gain / cost;
                if best.map_or(true, |(_, r)| ratio > r) {
                    best = Some((pos, ratio));
                }
            }
            let Some((pos, _)) = best else { break };
            let cur = cands[idx[pos]];
            idx[pos] += 1;
            total_bits += f64::from(cands[idx[pos]] - cur);
        }

        let schemes = profile
            .layers
            .iter()
            .enumerate()
            .map(|(pos, l)| {
                (l.layer, QuantScheme { bits: cands[idx[pos]], group_size: self.base.group_size })
            })
            .collect();
        Ok(BitPlan {
            schemes,
            mean_bits: (total_bits / n as f64) as f32,
            target_bits: self.target_bits,
            provenance: profile.provenance(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::LayerSensitivity;

    fn profile(layers: &[&[(u8, f32)]], group_tag: &str, cands: &[u8]) -> SensitivityProfile {
        SensitivityProfile {
            model: "nt-tiny".into(),
            method: "rtn".into(),
            group_tag: group_tag.into(),
            calib_source: "gen-v2".into(),
            loss: "dist".into(),
            candidate_bits: cands.to_vec(),
            layers: layers
                .iter()
                .enumerate()
                .map(|(i, scores)| LayerSensitivity {
                    layer: i,
                    scores: scores.iter().copied().collect(),
                })
                .collect(),
            ckpt_hash: None,
        }
    }

    #[test]
    fn floor_allocation_when_budget_is_tight() {
        let p = profile(&[&[(2, 1.0), (4, 0.1)], &[(2, 2.0), (4, 0.2)]], "g64", &[2, 4]);
        let plan = BitBudgetPlanner::new(QuantScheme::w2_g64(), 2.0).plan(&p).unwrap();
        assert_eq!(plan.mean_bits, 2.0);
        assert!(plan.schemes.values().all(|s| s.bits == 2));
    }

    #[test]
    fn upgrade_goes_to_the_fragile_layer_first() {
        // layer 1 is 10x more sensitive: a budget with room for one upgrade
        // must spend it there
        let p = profile(&[&[(2, 0.2), (4, 0.1)], &[(2, 2.0), (4, 0.1)]], "g64", &[2, 4]);
        let plan = BitBudgetPlanner::new(QuantScheme::w2_g64(), 3.0).plan(&p).unwrap();
        assert_eq!(plan.schemes[&0].bits, 2);
        assert_eq!(plan.schemes[&1].bits, 4);
        assert_eq!(plan.mean_bits, 3.0);
        assert_eq!(plan.layer_bits_string(), "0:2,1:4");
    }

    #[test]
    fn grain_mismatch_is_rejected() {
        let p = profile(&[&[(2, 1.0), (4, 0.1)]], "g64", &[2, 4]);
        let err = BitBudgetPlanner::new(QuantScheme::w4_perchannel(), 4.0)
            .plan(&p)
            .unwrap_err();
        assert!(format!("{err}").contains("grain"), "{err}");
    }

    #[test]
    fn plan_json_round_trips() {
        let p = profile(&[&[(2, 0.2), (4, 0.1)], &[(2, 2.0), (4, 0.1)]], "g64", &[2, 4]);
        let plan = BitBudgetPlanner::new(QuantScheme::w2_g64(), 3.0).plan(&p).unwrap();
        let j = plan.to_json();
        assert_eq!(j.get("schema").and_then(|v| v.as_str()), Some(PLAN_SCHEMA));
        let back = BitPlan::from_json(&Json::parse(&j.emit()).unwrap()).unwrap();
        assert_eq!(back, plan);
        // per-channel grain serializes as a null group and survives
        let p = profile(&[&[(4, 0.1), (8, 0.05)]], "pc", &[4, 8]);
        let plan = BitBudgetPlanner::new(QuantScheme::w4_perchannel(), 8.0)
            .plan(&p)
            .unwrap();
        let back = BitPlan::from_json(&Json::parse(&plan.to_json().emit()).unwrap()).unwrap();
        assert_eq!(back, plan);
        // unknown schema rejected
        assert!(BitPlan::from_json(&Json::parse(r#"{"schema":"v0"}"#).unwrap()).is_err());
    }

    #[test]
    fn zero_gain_upgrades_are_skipped() {
        // identical scores at every width: budget stays unspent at the floor
        let p = profile(&[&[(2, 1.0), (4, 1.0), (8, 1.0)]], "g64", &[2, 4, 8]);
        let plan = BitBudgetPlanner::new(QuantScheme::w2_g64(), 8.0).plan(&p).unwrap();
        assert_eq!(plan.schemes[&0].bits, 2);
    }
}

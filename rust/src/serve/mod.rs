//! Batched inference serving over a quantized model.
//!
//! A minimal but real dynamic batcher: client threads submit requests on an
//! mpsc channel; the serving loop drains up to `max_batch` of them (waiting
//! at most `batch_window` for stragglers), runs one batched generation, and
//! answers each request on its own reply channel.  This is the deployment
//! story of the paper — the quantized model serving traffic — and the
//! harness behind `bench_serve` / `examples/serve_quantized.rs`.
//!
//! (std-thread based: the async ecosystem is unavailable offline, and the
//! PJRT client is single-process anyway — the batcher, not the executor, is
//! the interesting part.)

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::eval::generate::{generate, SampleConfig};
use crate::eval::LanguageModel;

/// One generation request.
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// The server's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub tokens: Vec<i32>,
    /// time from submit to batch start
    pub queue_micros: u128,
    /// generation wall time of the batch this request rode in
    pub gen_micros: u128,
    pub batch_size: usize,
}

/// Server tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    pub max_batch: usize,
    pub batch_window: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 8, batch_window: Duration::from_millis(2) }
    }
}

/// Handle for submitting requests (cloneable across client threads).
#[derive(Clone)]
pub struct ServeHandle {
    tx: mpsc::Sender<Request>,
}

impl ServeHandle {
    /// Submit a prompt and block until the response arrives.
    pub fn submit(&self, prompt: Vec<i32>, max_new: usize) -> Result<Response> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request { prompt, max_new, enqueued: Instant::now(), reply })
            .map_err(|_| Error::Serve("server stopped".into()))?;
        rx.recv().map_err(|_| Error::Serve("server dropped request".into()))
    }

    /// Submit without waiting; returns the reply receiver.
    pub fn submit_async(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<mpsc::Receiver<Response>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request { prompt, max_new, enqueued: Instant::now(), reply })
            .map_err(|_| Error::Serve("server stopped".into()))?;
        Ok(rx)
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub served: usize,
    pub batches: usize,
    pub total_gen_micros: u128,
    /// summed submit-to-batch-start time across served requests — the
    /// batcher's own latency contribution, invisible in generation time
    pub total_queue_micros: u128,
    pub max_batch_seen: usize,
}

impl ServeStats {
    pub fn mean_batch(&self) -> f32 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f32 / self.batches as f32
        }
    }

    /// Mean time a request waited in the queue before its batch started.
    pub fn mean_queue_micros(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.total_queue_micros as f64 / self.served as f64
        }
    }
}

/// Build the (handle, receiver) pair for a serving loop.
pub fn channel() -> (ServeHandle, mpsc::Receiver<Request>) {
    let (tx, rx) = mpsc::channel();
    (ServeHandle { tx }, rx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::tensor::Tensor;

    /// Mock with an optional hard batch ceiling, like an AOT runner whose
    /// largest exported bucket is `cap`: anything bigger is the old
    /// mid-batch `Error::Artifact` failure. `cap: None` models an
    /// unbounded executor (the trait default).
    struct Bucketed {
        cfg: ModelConfig,
        cap: Option<usize>,
    }

    impl LanguageModel for Bucketed {
        fn config(&self) -> &ModelConfig {
            &self.cfg
        }

        fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
            let (b, s) = (tokens.shape[0], tokens.shape[1]);
            if b > self.cap.unwrap_or(usize::MAX) {
                return Err(Error::Msg(format!("batch {b} exceeds largest bucket")));
            }
            Ok(Tensor::f32(&[b, s, self.cfg.vocab],
                           vec![0.0; b * s * self.cfg.vocab]))
        }

        fn max_batch(&self) -> Option<usize> {
            self.cap
        }
    }

    #[test]
    fn oversized_drain_is_chunked_to_max_batch() {
        let model =
            Bucketed { cfg: ModelConfig::builtin("nt-tiny").unwrap(), cap: Some(2) };
        let (handle, rx) = channel();
        let replies: Vec<_> = (0..5)
            .map(|_| handle.submit_async(vec![1, 2], 2).unwrap())
            .collect();
        drop(handle);
        // max_batch 8 > the model's bucket: the drain of 5 must split 2/2/1
        let stats = serve_loop(
            &model,
            ServeConfig { max_batch: 8, batch_window: Duration::from_millis(100) },
            rx,
        )
        .unwrap();
        assert_eq!(stats.served, 5);
        assert_eq!(stats.max_batch_seen, 2);
        let mut queue_sum = 0u128;
        for rx in replies {
            let resp = rx.recv().expect("every rider answered");
            assert_eq!(resp.tokens.len(), 4);
            assert!(resp.batch_size <= 2);
            queue_sum += resp.queue_micros;
        }
        // the aggregate queue time is exactly what the riders saw
        assert_eq!(stats.total_queue_micros, queue_sum);
        assert_eq!(
            stats.mean_queue_micros(),
            queue_sum as f64 / stats.served as f64
        );
    }

    #[test]
    fn mean_queue_micros_handles_empty_and_divides() {
        assert_eq!(ServeStats::default().mean_queue_micros(), 0.0);
        let stats = ServeStats {
            served: 4,
            total_queue_micros: 400,
            ..Default::default()
        };
        assert_eq!(stats.mean_queue_micros(), 100.0);
    }

    #[test]
    fn unbounded_model_is_not_chunked() {
        // max_batch() == None (the trait default): the whole drain rides
        // in one batch
        let model = Bucketed { cfg: ModelConfig::builtin("nt-tiny").unwrap(), cap: None };
        let (handle, rx) = channel();
        let replies: Vec<_> = (0..3)
            .map(|_| handle.submit_async(vec![1], 1).unwrap())
            .collect();
        drop(handle);
        let stats = serve_loop(
            &model,
            ServeConfig { max_batch: 8, batch_window: Duration::from_millis(100) },
            rx,
        )
        .unwrap();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.batches, 1, "unbounded model must not be chunked");
        for rx in replies {
            assert_eq!(rx.recv().expect("answered").batch_size, 3);
        }
    }
}

/// Run the serving loop on the current thread until every handle is dropped.
///
/// A drain larger than the model's [`LanguageModel::max_batch`] (the
/// largest exported AOT batch bucket) is split into bucket-sized chunks and
/// generated chunk by chunk — an over-eager `max_batch` in [`ServeConfig`]
/// degrades to more batches instead of failing every rider with an
/// artifact error.
pub fn serve_loop(
    model: &dyn LanguageModel,
    cfg: ServeConfig,
    rx: mpsc::Receiver<Request>,
) -> Result<ServeStats> {
    let mut stats = ServeStats::default();
    let chunk_cap = model.max_batch().unwrap_or(usize::MAX).max(1);
    loop {
        // block for the first request of the batch
        let Ok(first) = rx.recv() else {
            return Ok(stats);
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + cfg.batch_window;
        while pending.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }

        while !pending.is_empty() {
            let rest = if pending.len() > chunk_cap {
                pending.split_off(chunk_cap)
            } else {
                Vec::new()
            };
            let batch = std::mem::replace(&mut pending, rest);

            let t0 = Instant::now();
            let seq = model.config().seq;
            let target = batch
                .iter()
                .map(|r| (r.prompt.len() + r.max_new).min(seq))
                .max()
                .unwrap();
            let prompts: Vec<Vec<i32>> = batch.iter().map(|r| r.prompt.clone()).collect();
            let outs = generate(
                model,
                &prompts,
                target,
                &SampleConfig { temperature: 0.0, stochastic_prefix: 0, seed: 0 },
            )?;
            let gen_micros = t0.elapsed().as_micros();
            let bs = batch.len();
            stats.batches += 1;
            stats.total_gen_micros += gen_micros;
            stats.max_batch_seen = stats.max_batch_seen.max(bs);
            for (req, tokens) in batch.into_iter().zip(outs) {
                let want = (req.prompt.len() + req.max_new).min(seq);
                let queue_micros = (t0 - req.enqueued).as_micros();
                let resp = Response {
                    tokens: tokens[..want].to_vec(),
                    queue_micros,
                    gen_micros,
                    batch_size: bs,
                };
                let _ = req.reply.send(resp);
                stats.total_queue_micros += queue_micros;
                stats.served += 1;
            }
        }
    }
}

//! Legacy single-model serving surface — now a thin shim over the
//! [`crate::engine`] scheduler.
//!
//! # Migration note
//!
//! `serve_loop` is **deprecated**: it serves exactly one model on the
//! calling thread with no deadlines, no cancellation, and no cache.  New
//! code should use [`crate::engine::Engine`]:
//!
//! ```text
//! // before                                  // after
//! let (handle, rx) = serve::channel();       let mut engine = Engine::builder()
//! ...spawn clients using handle...               .model("m", factory).build()?;
//! serve::serve_loop(&model, cfg, rx)?;       let client = engine.start()?;
//!                                            ...clients submit via client...
//!                                            let stats = engine.shutdown()?;
//! ```
//!
//! The shim keeps the old wire types (`Request`/`Response`/`ServeStats`)
//! and exit condition (the loop returns when every [`ServeHandle`] clone
//! has dropped), but batching, chunking, and queue-time accounting are the
//! engine scheduler's: queue time is measured against the dispatch-group
//! start with saturating math, so riders split across bucket-sized chunks
//! are not charged earlier chunks' generation time.  Three behavioral
//! differences: a failed generation no longer aborts the loop — the
//! affected riders' reply channels drop (their `submit` returns an error)
//! and serving continues; the first failure is re-surfaced when the
//! loop returns as an [`Error::Serve`] wrapping the original message,
//! where the old loop propagated the underlying variant (e.g.
//! `Error::Artifact`) immediately; and a malformed prompt (empty, or
//! longer than the model context) is rejected at routing — the legacy
//! reply sender drops, surfacing as the historical "server dropped
//! request" error — where the original loop truncated over-length
//! prompts.  Callers matching on specific variants should migrate to the
//! engine API.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::engine::scheduler::{Lane, Msg, Pending, ReplyTo, Scheduler};
use crate::engine::{ModelTuning, SampleConfig};
use crate::error::{Error, Result};
use crate::eval::LanguageModel;

/// One generation request.
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// The server's answer.
#[derive(Debug, Clone)]
pub struct Response {
    /// prompt + generated tokens
    pub tokens: Vec<i32>,
    /// length of the prompt prefix inside `tokens`
    pub prompt_len: usize,
    /// time from submit to dispatch of this request's batch group
    pub queue_micros: u128,
    /// summed wall time of every prefill/decode call this request rode
    pub gen_micros: u128,
    /// largest batch this request shared (prefill chunk or decode step)
    pub batch_size: usize,
}

impl Response {
    /// Only the newly generated tokens (everything after the prompt).
    pub fn new_tokens(&self) -> &[i32] {
        &self.tokens[self.prompt_len.min(self.tokens.len())..]
    }
}

/// Server tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    pub max_batch: usize,
    pub batch_window: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 8, batch_window: Duration::from_millis(2) }
    }
}

impl ServeConfig {
    /// Reject degenerate tunings (`max_batch == 0`, zero window) with a
    /// clear `Error::Config` instead of silently serving one-request
    /// batches.
    pub fn validate(&self) -> Result<()> {
        ModelTuning { max_batch: self.max_batch, batch_window: self.batch_window }
            .validate("serve_loop")
    }
}

/// Handle for submitting requests (cloneable across client threads).
#[derive(Clone)]
pub struct ServeHandle {
    tx: mpsc::Sender<Request>,
}

impl ServeHandle {
    /// Submit a prompt and block until the response arrives.
    pub fn submit(&self, prompt: Vec<i32>, max_new: usize) -> Result<Response> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request { prompt, max_new, enqueued: Instant::now(), reply })
            .map_err(|_| Error::Serve("server stopped".into()))?;
        rx.recv().map_err(|_| Error::Serve("server dropped request".into()))
    }

    /// Submit without waiting; returns the reply receiver.
    pub fn submit_async(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> Result<mpsc::Receiver<Response>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request { prompt, max_new, enqueued: Instant::now(), reply })
            .map_err(|_| Error::Serve("server stopped".into()))?;
        Ok(rx)
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub served: usize,
    pub batches: usize,
    pub total_gen_micros: u128,
    /// summed submit-to-dispatch time across served requests — the
    /// batcher's own latency contribution, invisible in generation time
    pub total_queue_micros: u128,
    pub max_batch_seen: usize,
}

impl ServeStats {
    pub fn mean_batch(&self) -> f32 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f32 / self.batches as f32
        }
    }

    /// Mean time a request waited in the queue before its batch started.
    pub fn mean_queue_micros(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.total_queue_micros as f64 / self.served as f64
        }
    }
}

/// Build the (handle, receiver) pair for a serving loop.
pub fn channel() -> (ServeHandle, mpsc::Receiver<Request>) {
    let (tx, rx) = mpsc::channel();
    (ServeHandle { tx }, rx)
}

/// Run a single-model serving loop on the current thread until every
/// [`ServeHandle`] is dropped.
///
/// Deprecated shim over the [`crate::engine`] scheduler (see the module
/// docs for the migration sketch).  A drain larger than the model's
/// [`LanguageModel::max_batch`] (the largest exported AOT batch bucket) is
/// still split into bucket-sized chunks, and all riders of one dispatch
/// group share the same submit-to-dispatch queue time.
#[deprecated(
    since = "0.5.0",
    note = "use engine::Engine: multi-model, deadlines, cancellation, warm-up, cache"
)]
pub fn serve_loop(
    model: &dyn LanguageModel,
    cfg: ServeConfig,
    rx: mpsc::Receiver<Request>,
) -> Result<ServeStats> {
    cfg.validate()?;
    let (tx, engine_rx) = mpsc::channel();
    // bridge thread: legacy Requests are Send even though the model is
    // not, so only the envelopes cross threads; when the last ServeHandle
    // drops, the bridge drops `tx` and the scheduler drains and exits
    let bridge = std::thread::spawn(move || {
        while let Ok(r) = rx.recv() {
            let pending = Pending {
                lane: 0,
                prompt: r.prompt,
                max_new: r.max_new,
                sample: SampleConfig { temperature: 0.0, stochastic_prefix: 0, seed: 0 },
                enqueued: r.enqueued,
                deadline: None,
                reply: ReplyTo::Legacy(r.reply),
                cancel: std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false)),
                seq: 0,
            };
            if tx.send(Msg::Submit(pending)).is_err() {
                break;
            }
        }
    });
    let tuning = ModelTuning { max_batch: cfg.max_batch, batch_window: cfg.batch_window };
    let lane = Lane::new("default".to_string(), model, tuning);
    let mut stats = Scheduler::new(vec![lane], engine_rx, 0).run();
    let _ = bridge.join();
    let m = stats.models.remove("default").unwrap_or_default();
    // the engine answers failed riders and keeps serving, but the old
    // serve_loop contract surfaced the underlying failure to its caller —
    // preserve that diagnosability after the drain
    if let Some(first) = m.first_error {
        return Err(Error::Serve(first));
    }
    Ok(m.to_serve_stats())
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::tensor::Tensor;

    /// Mock with an optional hard batch ceiling, like an AOT runner whose
    /// largest exported bucket is `cap`: anything bigger is the old
    /// mid-batch `Error::Artifact` failure. `cap: None` models an
    /// unbounded executor (the trait default).
    struct Bucketed {
        cfg: ModelConfig,
        cap: Option<usize>,
    }

    impl LanguageModel for Bucketed {
        fn config(&self) -> &ModelConfig {
            &self.cfg
        }

        fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
            let (b, s) = (tokens.shape[0], tokens.shape[1]);
            if b > self.cap.unwrap_or(usize::MAX) {
                return Err(Error::Msg(format!("batch {b} exceeds largest bucket")));
            }
            Ok(Tensor::f32(&[b, s, self.cfg.vocab],
                           vec![0.0; b * s * self.cfg.vocab]))
        }

        fn max_batch(&self) -> Option<usize> {
            self.cap
        }
    }

    #[test]
    fn oversized_drain_is_chunked_to_max_batch() {
        let model =
            Bucketed { cfg: ModelConfig::builtin("nt-tiny").unwrap(), cap: Some(2) };
        let (handle, rx) = channel();
        let replies: Vec<_> = (0..5)
            .map(|_| handle.submit_async(vec![1, 2], 2).unwrap())
            .collect();
        drop(handle);
        // max_batch 8 > the model's bucket: the drain of 5 must split 2/2/1
        let stats = serve_loop(
            &model,
            ServeConfig { max_batch: 8, batch_window: Duration::from_millis(100) },
            rx,
        )
        .unwrap();
        assert_eq!(stats.served, 5);
        assert_eq!(stats.max_batch_seen, 2);
        let mut queue_sum = 0u128;
        for rx in replies {
            let resp = rx.recv().expect("every rider answered");
            assert_eq!(resp.tokens.len(), 4);
            assert_eq!(resp.prompt_len, 2);
            assert_eq!(resp.new_tokens().len(), 2);
            assert!(resp.batch_size <= 2);
            queue_sum += resp.queue_micros;
        }
        // the aggregate queue time is exactly what the riders saw
        assert_eq!(stats.total_queue_micros, queue_sum);
        assert_eq!(
            stats.mean_queue_micros(),
            queue_sum as f64 / stats.served as f64
        );
    }

    /// A model slow enough that per-chunk accounting would be visible:
    /// with bucket cap 1 every rider is its own chunk, and the old
    /// accounting charged rider N the N-1 earlier chunks' generation time
    /// as queue time.  All riders must share the drain-start instant.
    #[test]
    fn chunk_riders_share_drain_start_queue_time() {
        struct Sleepy(ModelConfig);
        impl LanguageModel for Sleepy {
            fn config(&self) -> &ModelConfig {
                &self.0
            }
            fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
                std::thread::sleep(Duration::from_millis(40));
                let (b, s) = (tokens.shape[0], tokens.shape[1]);
                Ok(Tensor::f32(&[b, s, self.0.vocab], vec![0.0; b * s * self.0.vocab]))
            }
            fn max_batch(&self) -> Option<usize> {
                Some(1)
            }
        }
        let model = Sleepy(ModelConfig::builtin("nt-tiny").unwrap());
        let (handle, rx) = channel();
        let replies: Vec<_> = (0..3)
            .map(|_| handle.submit_async(vec![1, 2], 1).unwrap())
            .collect();
        drop(handle);
        let stats = serve_loop(
            &model,
            ServeConfig { max_batch: 8, batch_window: Duration::from_millis(50) },
            rx,
        )
        .unwrap();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.batches, 3, "bucket cap 1 chunks the drain into singles");
        let q: Vec<u128> = replies
            .iter()
            .map(|r| r.recv().expect("answered").queue_micros)
            .collect();
        // new accounting: spread == submit skew (microseconds); the old
        // per-chunk accounting would charge the last rider the ~80ms of
        // the two earlier chunks
        let spread = q.iter().max().unwrap() - q.iter().min().unwrap();
        assert!(
            spread < 40_000,
            "queue spread {spread}us: chunk riders were charged earlier \
             chunks' generation time"
        );
    }

    #[test]
    fn mean_queue_micros_handles_empty_and_divides() {
        assert_eq!(ServeStats::default().mean_queue_micros(), 0.0);
        let stats = ServeStats {
            served: 4,
            total_queue_micros: 400,
            ..Default::default()
        };
        assert_eq!(stats.mean_queue_micros(), 100.0);
    }

    #[test]
    fn unbounded_model_is_not_chunked() {
        // max_batch() == None (the trait default): the whole drain rides
        // in one batch
        let model = Bucketed { cfg: ModelConfig::builtin("nt-tiny").unwrap(), cap: None };
        let (handle, rx) = channel();
        let replies: Vec<_> = (0..3)
            .map(|_| handle.submit_async(vec![1], 1).unwrap())
            .collect();
        drop(handle);
        let stats = serve_loop(
            &model,
            ServeConfig { max_batch: 8, batch_window: Duration::from_millis(100) },
            rx,
        )
        .unwrap();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.batches, 1, "unbounded model must not be chunked");
        for rx in replies {
            assert_eq!(rx.recv().expect("answered").batch_size, 3);
        }
    }

    #[test]
    fn degenerate_config_rejected() {
        let model = Bucketed { cfg: ModelConfig::builtin("nt-tiny").unwrap(), cap: None };
        let (_handle, rx) = channel();
        let err = serve_loop(
            &model,
            ServeConfig { max_batch: 0, batch_window: Duration::from_millis(1) },
            rx,
        )
        .unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(format!("{err}").contains("max_batch"), "{err}");

        let (_handle, rx) = channel();
        let err = serve_loop(
            &model,
            ServeConfig { max_batch: 8, batch_window: Duration::ZERO },
            rx,
        )
        .unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(format!("{err}").contains("batch_window"), "{err}");
    }
}

//! `.ntz` tensor archive reader/writer — mirror of `python/compile/ntz.py`.
//!
//! Layout (little-endian):
//! `b"NTZ1" | u32 n | per tensor: u32 name_len, name, u8 dtype, u32 ndim,
//!  u64*ndim dims, raw data (C order)`.

// Justified unwraps: `chunks_exact(n)` slices always convert to `[u8; n]`
// (crate-wide `clippy::unwrap_used` opt-out).
#![allow(clippy::unwrap_used)]

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};

use super::dense::{DType, Storage, Tensor};

const MAGIC: &[u8; 4] = b"NTZ1";

/// Load every tensor in an `.ntz` archive, keyed by name.
pub fn load_ntz(path: impl AsRef<Path>) -> Result<BTreeMap<String, Tensor>> {
    let bytes = std::fs::read(path.as_ref()).map_err(|e| {
        Error::Checkpoint(format!("{}: {e}", path.as_ref().display()))
    })?;
    let mut r = &bytes[..];

    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Checkpoint(format!(
            "{}: bad magic {magic:?}",
            path.as_ref().display()
        )));
    }
    let n = read_u32(&mut r)?;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)
            .map_err(|e| Error::Checkpoint(format!("bad tensor name: {e}")))?;
        let mut code = [0u8; 1];
        r.read_exact(&mut code)?;
        let dtype = DType::from_code(code[0])?;
        let ndim = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u64(&mut r)? as usize);
        }
        let count: usize = shape.iter().product();
        let nbytes = count * dtype.size_of();
        let mut raw = vec![0u8; nbytes];
        r.read_exact(&mut raw)?;
        let data = decode(dtype, &raw);
        out.insert(name, Tensor { shape, data });
    }
    Ok(out)
}

/// Save tensors to an `.ntz` archive (sorted by name for determinism).
pub fn save_ntz(path: impl AsRef<Path>, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&[t.dtype().code()])?;
        f.write_all(&(t.rank() as u32).to_le_bytes())?;
        for d in &t.shape {
            f.write_all(&(*d as u64).to_le_bytes())?;
        }
        f.write_all(&encode(&t.data))?;
    }
    f.flush()?;
    Ok(())
}

fn read_u32(r: &mut &[u8]) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut &[u8]) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn decode(dtype: DType, raw: &[u8]) -> Storage {
    match dtype {
        DType::F32 => Storage::F32(
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::I8 => Storage::I8(raw.iter().map(|&b| b as i8).collect()),
        DType::U8 => Storage::U8(raw.to_vec()),
        DType::I32 => Storage::I32(
            raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::I64 => Storage::I64(
            raw.chunks_exact(8).map(|c| i64::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
    }
}

fn encode(s: &Storage) -> Vec<u8> {
    match s {
        Storage::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        Storage::I8(v) => v.iter().map(|&x| x as u8).collect(),
        Storage::U8(v) => v.clone(),
        Storage::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        Storage::I64(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_dtypes() {
        let dir = std::env::temp_dir().join("ntz_test_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ntz");
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), Tensor::f32(&[2, 3], vec![1., -2., 3.5, 0., 5., 6.]));
        m.insert("b".to_string(), Tensor::i8(&[4], vec![-7, 0, 7, 127]));
        m.insert("c".to_string(), Tensor::u8(&[2], vec![0, 255]));
        m.insert("d".to_string(), Tensor::i32(&[2, 2], vec![1, -1, 1 << 20, 0]));
        m.insert("e".to_string(), Tensor::i64(&[1], vec![-(1i64 << 40)]));
        save_ntz(&path, &m).unwrap();
        let back = load_ntz(&path).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn missing_file_errors() {
        assert!(load_ntz("/nonexistent/definitely/missing.ntz").is_err());
    }

    #[test]
    fn bad_magic_errors() {
        let dir = std::env::temp_dir().join("ntz_test_badmagic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ntz");
        std::fs::write(&path, b"NOPE\x00\x00\x00\x00").unwrap();
        assert!(load_ntz(&path).is_err());
    }
}

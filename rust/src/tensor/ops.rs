//! CPU reference math used by quantization substrates and tests.
//!
//! These are *not* the hot path (XLA executables are) — they back GPTQ's
//! Hessian algebra, SmoothQuant's scale migration, unit tests, and the
//! pure-Rust fallbacks.  `matmul` is rayon-parallel because GPTQ's weight
//! reconstruction calls it on full layers.

use crate::error::{Error, Result};
use crate::util::parallel::par_chunks_mut;

use super::dense::Tensor;

/// Row-major matmul: `a [M,K] @ b [K,N] -> [M,N]` (threaded over rows).
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.rank() != 2 || b.rank() != 2 || a.shape[1] != b.shape[0] {
        return Err(Error::Shape(format!(
            "matmul {:?} x {:?}",
            a.shape, b.shape
        )));
    }
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    let av = a.as_f32()?;
    let bv = b.as_f32()?;
    let mut out = vec![0.0f32; m * n];
    par_chunks_mut(&mut out, n, |i, row| {
        let arow = &av[i * k..(i + 1) * k];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &bv[kk * n..(kk + 1) * n];
            for (j, r) in row.iter_mut().enumerate() {
                *r += aik * brow[j];
            }
        }
    });
    Ok(Tensor::f32(&[m, n], out))
}

/// Transpose a 2-D f32 tensor.
pub fn transpose2d(a: &Tensor) -> Result<Tensor> {
    if a.rank() != 2 {
        return Err(Error::Shape("transpose2d needs rank 2".into()));
    }
    let (m, n) = (a.shape[0], a.shape[1]);
    let av = a.as_f32()?;
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = av[i * n + j];
        }
    }
    Ok(Tensor::f32(&[n, m], out))
}

/// Per-channel (last-dim) mean and population variance over leading dims —
/// CPU mirror of the `channel_stats` kernel / Eq. 2's reduction.
pub fn mean_var_channels(x: &Tensor) -> Result<(Vec<f32>, Vec<f32>)> {
    let c = *x.shape.last().ok_or_else(|| Error::Shape("empty shape".into()))?;
    let rows = x.numel() / c;
    let v = x.as_f32()?;
    let mut mean = vec![0.0f64; c];
    let mut sq = vec![0.0f64; c];
    for r in 0..rows {
        let row = &v[r * c..(r + 1) * c];
        for (j, &val) in row.iter().enumerate() {
            mean[j] += val as f64;
            sq[j] += (val as f64) * (val as f64);
        }
    }
    let nf = rows as f64;
    let mu: Vec<f32> = mean.iter().map(|&s| (s / nf) as f32).collect();
    let var: Vec<f32> = sq
        .iter()
        .zip(&mu)
        .map(|(&s, &m)| (s / nf - (m as f64) * (m as f64)) as f32)
        .collect();
    Ok((mu, var))
}

/// Max absolute elementwise difference between two same-shape f32 tensors.
pub fn max_abs_diff(a: &Tensor, b: &Tensor) -> Result<f32> {
    if a.shape != b.shape {
        return Err(Error::Shape(format!("{:?} vs {:?}", a.shape, b.shape)));
    }
    let (av, bv) = (a.as_f32()?, b.as_f32()?);
    Ok(av
        .iter()
        .zip(bv)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max))
}

/// True when every element differs by at most `atol + rtol * |b|`.
pub fn allclose(a: &Tensor, b: &Tensor, atol: f32, rtol: f32) -> Result<bool> {
    if a.shape != b.shape {
        return Err(Error::Shape(format!("{:?} vs {:?}", a.shape, b.shape)));
    }
    let (av, bv) = (a.as_f32()?, b.as_f32()?);
    Ok(av
        .iter()
        .zip(bv)
        .all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::f32(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::f32(&[2, 2], vec![1., 1., 1., 1.]);
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.as_f32().unwrap(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_shape_error() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(matmul(&a, &b).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::randn(&[3, 5], 1, 1.0);
        let t = transpose2d(&a).unwrap();
        assert_eq!(t.shape, vec![5, 3]);
        let back = transpose2d(&t).unwrap();
        assert_eq!(a, back);
    }

    #[test]
    fn mean_var_known() {
        // columns: [1,3] -> mu 2 var 1 ; [2,2] -> mu 2 var 0
        let x = Tensor::f32(&[2, 2], vec![1., 2., 3., 2.]);
        let (mu, var) = mean_var_channels(&x).unwrap();
        assert_eq!(mu, vec![2., 2.]);
        assert_eq!(var, vec![1., 0.]);
    }

    #[test]
    fn allclose_and_maxdiff() {
        let a = Tensor::f32(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::f32(&[3], vec![1.0, 2.0, 3.001]);
        assert!(allclose(&a, &b, 1e-2, 0.0).unwrap());
        assert!(!allclose(&a, &b, 1e-5, 0.0).unwrap());
        assert!((max_abs_diff(&a, &b).unwrap() - 0.001).abs() < 1e-6);
    }
}

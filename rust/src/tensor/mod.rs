//! Minimal dense tensor types for the coordinator's CPU-side bookkeeping.
//!
//! The heavy math runs inside AOT-compiled XLA executables; this module only
//! needs enough to hold weights/activations, quantize/pack them, move them in
//! and out of PJRT literals, and verify numerics in tests.

mod dense;
mod ntz;
mod ops;
mod pack;

pub use dense::{DType, Storage, Tensor};
pub use ntz::{load_ntz, save_ntz};
pub use ops::{allclose, matmul, max_abs_diff, mean_var_channels, transpose2d};
pub use pack::{pack_codes, packed_len, unpack_codes, PackedCodes};

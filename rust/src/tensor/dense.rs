//! Dense row-major tensor over f32 / i8 / u8 / i32 / i64 storage.

use crate::error::{Error, Result};

/// Element type of a [`Tensor`]. Codes match the `.ntz` on-disk format and
/// the Python side (`python/compile/ntz.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I8,
    U8,
    I32,
    I64,
}

impl DType {
    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I8 => 1,
            DType::U8 => 2,
            DType::I32 => 3,
            DType::I64 => 4,
        }
    }

    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::U8,
            3 => DType::I32,
            4 => DType::I64,
            _ => return Err(Error::msg(format!("unknown dtype code {c}"))),
        })
    }

    pub fn size_of(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
            DType::I64 => 8,
        }
    }
}

/// Typed storage backing a tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum Storage {
    F32(Vec<f32>),
    I8(Vec<i8>),
    U8(Vec<u8>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl Storage {
    pub fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I8(v) => v.len(),
            Storage::U8(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::I64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Storage::F32(_) => DType::F32,
            Storage::I8(_) => DType::I8,
            Storage::U8(_) => DType::U8,
            Storage::I32(_) => DType::I32,
            Storage::I64(_) => DType::I64,
        }
    }
}

/// A dense row-major (C-order) tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Storage,
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Storage::F32(data) }
    }

    pub fn i8(shape: &[usize], data: Vec<i8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Storage::I8(data) }
    }

    pub fn u8(shape: &[usize], data: Vec<u8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Storage::U8(data) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Storage::I32(data) }
    }

    pub fn i64(shape: &[usize], data: Vec<i64>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Storage::I64(data) }
    }

    /// All-zeros f32 tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor::f32(shape, vec![0.0; shape.iter().product()])
    }

    /// All-ones f32 tensor.
    pub fn ones(shape: &[usize]) -> Self {
        Tensor::f32(shape, vec![1.0; shape.iter().product()])
    }

    /// Deterministic pseudo-random f32 tensor in [-scale, scale] (tests/benches).
    pub fn randn(shape: &[usize], seed: u64, scale: f32) -> Self {
        let n: usize = shape.iter().product();
        let mut rng = crate::calib::rng::SplitMix64::new(seed);
        let data = (0..n)
            .map(|_| {
                // sum of 4 uniforms ~ approx gaussian, centered
                let s: f32 = (0..4)
                    .map(|_| (rng.next_u64() >> 40) as f32 / (1u64 << 24) as f32)
                    .sum();
                (s - 2.0) * scale
            })
            .collect();
        Tensor::f32(shape, data)
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Borrow as f32 slice; error if not F32.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Storage::F32(v) => Ok(v),
            other => Err(Error::Shape(format!("expected f32, got {:?}", other.dtype()))),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Storage::F32(v) => Ok(v),
            other => Err(Error::Shape(format!("expected f32, got {:?}", other.dtype()))),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            Storage::I8(v) => Ok(v),
            other => Err(Error::Shape(format!("expected i8, got {:?}", other.dtype()))),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match &self.data {
            Storage::U8(v) => Ok(v),
            other => Err(Error::Shape(format!("expected u8, got {:?}", other.dtype()))),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Storage::I32(v) => Ok(v),
            other => Err(Error::Shape(format!("expected i32, got {:?}", other.dtype()))),
        }
    }

    /// Reshape in place (numel must match).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.numel() {
            return Err(Error::Shape(format!(
                "reshape {:?} -> {:?}: numel mismatch",
                self.shape, shape
            )));
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Row `i` of a 2-D f32 tensor.
    pub fn row(&self, i: usize) -> Result<&[f32]> {
        if self.rank() != 2 {
            return Err(Error::Shape("row() needs rank 2".into()));
        }
        let cols = self.shape[1];
        Ok(&self.as_f32()?[i * cols..(i + 1) * cols])
    }

    /// Memory footprint of the raw data in bytes.
    pub fn nbytes(&self) -> usize {
        self.numel() * self.dtype().size_of()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_accessors() {
        let t = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.rank(), 2);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.row(1).unwrap(), &[4., 5., 6.]);
        assert_eq!(t.nbytes(), 24);
    }

    #[test]
    fn reshape_checks_numel() {
        let t = Tensor::zeros(&[4, 4]);
        assert!(t.clone().reshape(&[2, 8]).is_ok());
        assert!(t.reshape(&[3, 5]).is_err());
    }

    #[test]
    #[should_panic]
    fn shape_data_mismatch_panics() {
        let _ = Tensor::f32(&[2, 2], vec![1.0]);
    }

    #[test]
    fn dtype_roundtrip() {
        for d in [DType::F32, DType::I8, DType::U8, DType::I32, DType::I64] {
            assert_eq!(DType::from_code(d.code()).unwrap(), d);
        }
        assert!(DType::from_code(99).is_err());
    }

    #[test]
    fn randn_deterministic() {
        let a = Tensor::randn(&[16], 42, 1.0);
        let b = Tensor::randn(&[16], 42, 1.0);
        assert_eq!(a, b);
        let c = Tensor::randn(&[16], 43, 1.0);
        assert_ne!(a, c);
    }
}

//! Bit-packing for 2/4/8-bit weight codes.
//!
//! The deployed memory layout: symmetric codes are stored offset-binary in
//! packed `u8` words (4 codes/byte at 2-bit, 2 codes/byte at 4-bit).  This is
//! where the paper's 8x/4x memory reduction actually materializes; the PJRT
//! graphs take *unpacked* i8 codes (the CPU plugin has no sub-byte dtypes),
//! so the runtime unpacks on load — documented in DESIGN.md as the simulation
//! boundary of the CUDA sub-byte GEMM.

use crate::error::{Error, Result};

/// Packed weight codes + the metadata to unpack them.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedCodes {
    pub bits: u8,
    /// unpacked logical length (number of codes)
    pub len: usize,
    pub data: Vec<u8>,
}

/// Number of bytes needed to pack `len` codes at `bits` bits each.
pub fn packed_len(len: usize, bits: u8) -> usize {
    let per = 8 / bits as usize;
    len.div_ceil(per)
}

/// Pack signed symmetric codes (range `[-qmax, qmax]`) into offset-binary.
pub fn pack_codes(codes: &[i8], bits: u8) -> Result<PackedCodes> {
    if ![2, 4, 8].contains(&bits) {
        return Err(Error::Quant(format!("unsupported pack width {bits}")));
    }
    let qmax = (1i16 << (bits - 1)) - 1;
    let offset = qmax; // map [-qmax, qmax] -> [0, 2*qmax]
    let per = 8 / bits as usize;
    let mut data = vec![0u8; packed_len(codes.len(), bits)];
    for (i, &c) in codes.iter().enumerate() {
        let c16 = c as i16;
        if c16 < -qmax || c16 > qmax {
            return Err(Error::Quant(format!(
                "code {c} out of range for {bits}-bit symmetric"
            )));
        }
        let u = (c16 + offset) as u8;
        let byte = i / per;
        let slot = i % per;
        data[byte] |= u << (slot * bits as usize);
    }
    Ok(PackedCodes { bits, len: codes.len(), data })
}

/// Unpack offset-binary codes back to signed i8.
pub fn unpack_codes(p: &PackedCodes) -> Vec<i8> {
    let bits = p.bits as usize;
    let qmax = ((1i16 << (p.bits - 1)) - 1) as i16;
    let per = 8 / bits;
    let mask = if bits == 8 { 0xffu8 } else { (1u8 << bits) - 1 };
    let mut out = Vec::with_capacity(p.len);
    for i in 0..p.len {
        let byte = p.data[i / per];
        let u = (byte >> ((i % per) * bits)) & mask;
        out.push((u as i16 - qmax) as i8);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_4bit() {
        let codes: Vec<i8> = (-7..=7).collect();
        let p = pack_codes(&codes, 4).unwrap();
        assert_eq!(p.data.len(), packed_len(codes.len(), 4));
        assert_eq!(unpack_codes(&p), codes);
    }

    #[test]
    fn roundtrip_2bit() {
        let codes: Vec<i8> = vec![-1, 0, 1, 1, 0, -1, -1, 1, 0];
        let p = pack_codes(&codes, 2).unwrap();
        assert_eq!(p.data.len(), 3); // 9 codes at 4/byte
        assert_eq!(unpack_codes(&p), codes);
    }

    #[test]
    fn roundtrip_8bit() {
        let codes: Vec<i8> = vec![-127, -1, 0, 1, 127];
        let p = pack_codes(&codes, 8).unwrap();
        assert_eq!(unpack_codes(&p), codes);
    }

    #[test]
    fn out_of_range_rejected() {
        assert!(pack_codes(&[2], 2).is_err());
        assert!(pack_codes(&[-8], 4).is_err()); // symmetric range is [-7, 7]
        assert!(pack_codes(&[-128], 8).is_err());
    }

    #[test]
    fn bad_width_rejected() {
        assert!(pack_codes(&[0], 3).is_err());
    }

    #[test]
    fn memory_reduction_ratio() {
        // the paper's deployment claim: 2-bit is 16x smaller than f32
        let codes = vec![0i8; 1024];
        let p = pack_codes(&codes, 2).unwrap();
        assert_eq!(p.data.len() * 16, 1024 * 4);
    }
}

//! Runtime-graph latency: embed / block_fwd / block_fwd_q / head / stats
//! executions through PJRT (the per-layer costs every pipeline step pays).
//! Requires `make artifacts`.

use normtweak::coordinator::{FloatModel, QuantModel};
use normtweak::model::ModelWeights;
use normtweak::quant::QuantScheme;
use normtweak::runtime::Runtime;
use normtweak::tensor::Tensor;
use normtweak::util::bench::{bench_for, black_box};
use std::time::Duration;

fn main() {
    let artifacts = std::env::var("NT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    println!("== bench_kernels ==");
    let rt = Runtime::new(&artifacts).unwrap();
    let budget = Duration::from_millis(600);

    for model in ["nt-tiny", "nt-small", "nt-medium"] {
        let Ok(w) = ModelWeights::load_from_dir(model, &artifacts) else {
            eprintln!("[skip] weights for {model} missing");
            continue;
        };
        let fm = FloatModel::new(&rt, &w).unwrap();
        let cfg = &w.config;
        let toks = Tensor::i32(&[8, cfg.seq], vec![42; 8 * cfg.seq]);
        let x = Tensor::randn(&[8, cfg.seq, cfg.d_model], 3, 1.0);
        let tokens_per = (8 * cfg.seq) as f64;

        let r = bench_for(&format!("{model} embed.b8"), budget, || {
            black_box(fm.embed(&toks).unwrap());
        });
        println!("{}  [{:.0} ktok/s]", r.report(), r.throughput(tokens_per) / 1e3);

        let r = bench_for(&format!("{model} block_fwd.b8"), budget, || {
            black_box(fm.block_fwd(0, &x).unwrap());
        });
        println!("{}  [{:.0} ktok/s]", r.report(), r.throughput(tokens_per) / 1e3);

        // quantized block (W4 per-channel, RTN is fine for timing)
        let stream = normtweak::calib::corpus::token_stream(
            &normtweak::calib::corpus::wiki_syn(),
            rt.manifest.calib_batch * cfg.seq,
        );
        let calib = normtweak::calib::CalibSet::from_stream(
            &stream, rt.manifest.calib_batch, cfg.seq, "wiki-syn").unwrap();
        let pcfg = normtweak::coordinator::PipelineConfig::new(
            "rtn", QuantScheme::w4_perchannel());
        let (qm, _) =
            normtweak::coordinator::quantize_model(&rt, &w, &calib, &pcfg).unwrap();
        let qr = QuantModel::new(&rt, &qm).unwrap();
        let r = bench_for(&format!("{model} block_fwd_q.pc.b8"), budget, || {
            black_box(qr.block_fwd_q(0, &x).unwrap());
        });
        println!("{}  [{:.0} ktok/s]", r.report(), r.throughput(tokens_per) / 1e3);

        let r = bench_for(&format!("{model} head.b8"), budget, || {
            black_box(fm.head(&x).unwrap());
        });
        println!("{}  [{:.0} ktok/s]", r.report(), r.throughput(tokens_per) / 1e3);

        let xc = Tensor::randn(&[rt.manifest.calib_batch, cfg.seq, cfg.d_model], 4, 1.0);
        let r = bench_for(&format!("{model} channel_stats.b32"), budget, || {
            black_box(fm.channel_stats(&xc).unwrap());
        });
        println!("{}", r.report());
        println!();
    }
}

//! Quantization-substrate throughput: RTN / OmniQuant / GPTQ / pack-unpack
//! per layer size (the CPU-side cost of Algorithm 1's line 9).

use normtweak::quant::gptq::{GptqParams, Hessian};
use normtweak::quant::{gptq, omniquant, rtn, QuantScheme};
use normtweak::tensor::{matmul, pack_codes, transpose2d, unpack_codes, Tensor};
use normtweak::util::bench::{bench_for, black_box};
use std::time::Duration;

fn main() {
    println!("== bench_quant ==");
    let budget = Duration::from_millis(400);

    for (k, n, label) in [(256usize, 768usize, "qkv d=256"),
                          (1024, 256, "fc2 d=256"),
                          (1536, 384, "fc2 d=384")] {
        let w = Tensor::randn(&[k, n], 7, 1.0);
        let elems = (k * n) as f64;

        for scheme in [QuantScheme::w4_perchannel(), QuantScheme::w2_g64()] {
            let tag = format!("rtn {label} w{}{}", scheme.bits,
                              if scheme.group_size.is_some() { "g64" } else { "" });
            let r = bench_for(&tag, budget, || {
                black_box(rtn::quantize(&w, &scheme).unwrap());
            });
            println!("{}  [{:.1} Melem/s]", r.report(), r.throughput(elems) / 1e6);
        }

        let r = bench_for(&format!("omniquant {label} w2g64"), budget, || {
            black_box(omniquant::quantize(&w, &QuantScheme::w2_g64()).unwrap());
        });
        println!("{}  [{:.1} Melem/s]", r.report(), r.throughput(elems) / 1e6);

        // GPTQ with a real (correlated) Hessian
        let x = Tensor::randn(&[512, k], 8, 1.0);
        let xtx = matmul(&transpose2d(&x).unwrap(), &x).unwrap();
        let mut h = Hessian::new(k);
        h.accumulate(&xtx, 512).unwrap();
        let r = bench_for(&format!("gptq {label} w4"), Duration::from_millis(800), || {
            black_box(
                gptq::quantize(&w, &h, &QuantScheme::w4_perchannel(),
                               &GptqParams::default())
                .unwrap(),
            );
        });
        println!("{}  [{:.1} Melem/s]", r.report(), r.throughput(elems) / 1e6);

        let q = rtn::quantize(&w, &QuantScheme::w4_perchannel()).unwrap();
        let r = bench_for(&format!("pack+unpack {label} 4bit"), budget, || {
            let p = pack_codes(&q.codes, 4).unwrap();
            black_box(unpack_codes(&p));
        });
        println!("{}  [{:.1} Melem/s]", r.report(), r.throughput(elems) / 1e6);
        println!();
    }
}

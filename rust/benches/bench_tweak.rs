//! Tweak-step hot path: the fused XLA tweak iteration and its CPU-side
//! components (codes unpack, loss reference, Adam mirror).
//! Requires `make artifacts`.

use normtweak::calib::CalibSet;
use normtweak::coordinator::{quantize_model, FloatModel, PipelineConfig};
use normtweak::model::ModelWeights;
use normtweak::quant::QuantScheme;
use normtweak::runtime::Runtime;
use normtweak::tensor::Tensor;
use normtweak::tweak::adam::AdamState;
use normtweak::tweak::tweaker::{TweakTarget, Tweaker};
use normtweak::tweak::{loss, TweakConfig};
use normtweak::util::bench::{bench, bench_for, black_box};
use std::time::Duration;

fn main() {
    let artifacts = std::env::var("NT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    println!("== bench_tweak ==");
    let rt = Runtime::new(&artifacts).unwrap();
    let model = "nt-small";
    let w = ModelWeights::load_from_dir(model, &artifacts).unwrap();
    let cfg = &w.config;

    // build a quantized model to tweak
    let stream = normtweak::calib::corpus::token_stream(
        &normtweak::calib::corpus::wiki_syn(),
        rt.manifest.calib_batch * cfg.seq,
    );
    let calib = CalibSet::from_stream(&stream, rt.manifest.calib_batch,
                                      cfg.seq, "wiki-syn").unwrap();
    let pcfg = PipelineConfig::new("gptq", QuantScheme::w4_perchannel());
    let (qm, _) = quantize_model(&rt, &w, &calib, &pcfg).unwrap();

    let fm = FloatModel::new(&rt, &w).unwrap();
    let x = fm.embed(&calib.tokens).unwrap();
    let y_f = fm.block_fwd(0, &x).unwrap();
    let (mu, var) = fm.channel_stats(&y_f).unwrap();

    // the fused tweak_step executable (one PJRT call = one Adam iteration)
    let tweaker = Tweaker::new(&rt, model, "pc",
                               TweakConfig { iters: 1, ..TweakConfig::default() });
    let mut blk = qm.blocks[0].clone();
    let target = TweakTarget::Stats { mu: mu.clone(), var: var.clone() };
    let r = bench(&format!("{model} tweak_step (fused XLA)"), 2, 12, || {
        black_box(
            tweaker
                .tweak_layer(&mut blk, cfg.norm, &x, &target, 1e-3)
                .unwrap(),
        );
    });
    println!("{}", r.report());

    // CPU-side components
    let budget = Duration::from_millis(300);
    // measure the raw bit-unpack (codes_tensor() caches after the first
    // call — the serving decode path depends on that)
    let r = bench_for("codes unpack (qkv)", budget, || {
        black_box(qm.blocks[0].qkv.codes_tensor_owned());
    });
    println!("{}", r.report());

    let a = Tensor::randn(&[rt.manifest.calib_batch * cfg.seq, cfg.d_model], 1, 1.0);
    let b = Tensor::randn(&[rt.manifest.calib_batch * cfg.seq, cfg.d_model], 2, 1.0);
    let r = bench_for("dist_loss CPU reference", budget, || {
        black_box(loss::dist_loss(&a, &b).unwrap());
    });
    println!("{}", r.report());

    let mut adam = AdamState::new(4, cfg.d_model);
    let mut theta: Vec<Tensor> = (0..4).map(|i| Tensor::randn(&[cfg.d_model], i, 1.0)).collect();
    let grads: Vec<Tensor> = (0..4).map(|i| Tensor::randn(&[cfg.d_model], 10 + i, 0.1)).collect();
    let r = bench_for("adam CPU mirror (4 params)", budget, || {
        adam.apply_cpu(&mut theta, &grads, 1e-3);
    });
    println!("{}", r.report());
}

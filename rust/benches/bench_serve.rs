//! Serving throughput/latency of the quantized model under synthetic load
//! (batched vs unbatched — the dynamic batcher's win).
//! Requires `make artifacts`.

use std::time::{Duration, Instant};

use normtweak::calib::CalibSet;
use normtweak::coordinator::{quantize_model, PipelineConfig, QuantModel};
use normtweak::model::ModelWeights;
use normtweak::quant::QuantScheme;
use normtweak::runtime::Runtime;
use normtweak::serve::{channel, serve_loop, ServeConfig};

fn drive(model: &QuantModel, max_batch: usize, n_requests: usize) -> (f64, f64, f64) {
    let (handle, rx) = channel();
    let lat = std::sync::Mutex::new(Vec::<u128>::new());
    let t0 = Instant::now();
    let stats = std::thread::scope(|s| {
        for c in 0..4 {
            let h = handle.clone();
            let lat = &lat;
            s.spawn(move || {
                for i in 0..n_requests / 4 {
                    let prompt = vec![1, (8 + (c * 31 + i * 13) % 150) as i32];
                    let t = Instant::now();
                    if h.submit(prompt, 8).is_ok() {
                        lat.lock().unwrap().push(t.elapsed().as_micros());
                    }
                }
            });
        }
        drop(handle);
        serve_loop(
            model,
            ServeConfig { max_batch, batch_window: Duration::from_millis(10) },
            rx,
        )
    })
    .unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let mut l = lat.into_inner().unwrap();
    l.sort_unstable();
    let p50 = l[l.len() / 2] as f64 / 1000.0;
    (stats.served as f64 / wall, p50, stats.mean_queue_micros() / 1000.0)
}

fn main() {
    let artifacts = std::env::var("NT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    println!("== bench_serve ==");
    let rt = Runtime::new(&artifacts).unwrap();
    let w = ModelWeights::load_from_dir("nt-tiny", &artifacts).unwrap();
    let stream = normtweak::calib::corpus::token_stream(
        &normtweak::calib::corpus::wiki_syn(),
        rt.manifest.calib_batch * w.config.seq,
    );
    let calib = CalibSet::from_stream(&stream, rt.manifest.calib_batch,
                                      w.config.seq, "wiki-syn").unwrap();
    let cfg = PipelineConfig::new("rtn", QuantScheme::w4_perchannel());
    let (qm, _) = quantize_model(&rt, &w, &calib, &cfg).unwrap();
    let model = QuantModel::new(&rt, &qm).unwrap();

    // warm the executable cache
    drive(&model, 8, 8);

    for max_batch in [1usize, 4, 8] {
        let (rps, p50, queue) = drive(&model, max_batch, 32);
        println!(
            "max_batch {max_batch}: {rps:>6.1} req/s   p50 {p50:>7.1} ms   \
             mean queue {queue:>7.1} ms"
        );
    }
}

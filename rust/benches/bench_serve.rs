//! Serving throughput/latency of the engine under synthetic load, sweeping
//! `max_batch` (batched vs unbatched — the dynamic batcher's win) and
//! exercising the greedy response cache.
//!
//! Always emits machine-readable `BENCH_serve.json` (req/s, client-side
//! p50/p99 latency, engine-measured queue/prefill/decode-step/e2e
//! percentiles, decode fast-path health — KV-arena occupancy and
//! admission batch sizes — mean batch, cache hit rate per config) so the
//! serving perf trajectory is tracked across PRs: with `make artifacts`
//! present it serves a real RTN-quantized checkpoint; otherwise it falls
//! back to an offline mock model so the numbers still exist (tagged
//! `"model": "mock"`); the mock serves the slot arena too, so the fast
//! path is benchmarked either way.
//! Set `NT_BENCH_OUT` to redirect the JSON; pass `--trace out.json` to
//! export a Chrome trace of the whole sweep.

use std::sync::Arc;
use std::time::{Duration, Instant};

use normtweak::calib::CalibSet;
use normtweak::coordinator::{quantize_model, PipelineConfig};
use normtweak::engine::{Engine, GenRequest, ModelStats, ModelTuning, ServableModel};
use normtweak::error::Result;
use normtweak::eval::decode::{self, lock_arena};
use normtweak::eval::{ArenaSlot, DecodeSession, KvArena, KvCache, LanguageModel, SharedKvArena};
use normtweak::model::{ModelConfig, ModelWeights};
use normtweak::obs::trace::TraceCollector;
use normtweak::quant::QuantScheme;
use normtweak::runtime::Runtime;
use normtweak::tensor::Tensor;
use normtweak::util::json::{self, Json};

/// Offline stand-in: always prefers (last_token + 1) % vocab, no batch cap.
///
/// The mock serves the decode fast path for real: admitted prompts take
/// slots in a small KV arena and their steps run O(vocab) off the session's
/// own tail token, while overflow sessions (arena full) ride the
/// O(seq·vocab) full-context recompute fallback — so the bench exercises
/// the arena plumbing (slot reuse, batched admission, occupancy gauges)
/// and the `fast_path` block reports real occupancy even without
/// artifacts.
struct MockLm {
    cfg: ModelConfig,
    arena: SharedKvArena,
}

/// Arena capacity of the mock (comfortably above the bench's deepest
/// `max_batch` sweep point, so steady-state decode stays on the fast path).
const MOCK_SLOTS: usize = 16;

impl MockLm {
    fn new(cfg: ModelConfig) -> Self {
        // the mock never materialises K/V rows, so the arena tensors are
        // kept minimal (1 layer × 1 head × 1-wide values): what matters
        // here is the slot accounting, not the cache payload
        let arena = KvArena::shared(1, 1, cfg.seq, 1, MOCK_SLOTS);
        MockLm { cfg, arena }
    }
}

impl LanguageModel for MockLm {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn logits(&self, tokens: &Tensor) -> Result<Tensor> {
        let (b, s) = (tokens.shape[0], tokens.shape[1]);
        let v = self.cfg.vocab;
        let tv = tokens.as_i32()?;
        let mut out = vec![0.0f32; b * s * v];
        for i in 0..b {
            for t in 0..s {
                let next = ((tv[i * s + t] + 1) as usize) % v;
                out[(i * s + t) * v + next] = 10.0;
            }
        }
        Ok(Tensor::f32(&[b, s, v], out))
    }

    fn supports_decode(&self) -> bool {
        true
    }

    fn kv_arena(&self) -> Option<SharedKvArena> {
        Some(self.arena.clone())
    }

    fn prefill(&self, prompts: &[Vec<i32>]) -> Result<Vec<DecodeSession>> {
        let mut sessions = decode::recompute_prefill(self, prompts)?;
        // batched admission: one reservation covers every newcomer, or —
        // when the arena is full — the whole group stays on recompute
        let ids = lock_arena(&self.arena).try_reserve(prompts.len());
        if let Some(ids) = ids {
            let mut g = lock_arena(&self.arena);
            for (s, slot) in sessions.iter_mut().zip(ids) {
                let last = *s.tokens.last().unwrap_or(&0);
                g.note(slot, last, (s.tokens.len() - 1) as i32);
                s.kv = KvCache::Slot(ArenaSlot::new(self.arena.clone(), slot));
            }
        }
        Ok(sessions)
    }

    fn decode_step(&self, sessions: &mut [&mut DecodeSession]) -> Result<()> {
        let v = self.cfg.vocab;
        let mut rest: Vec<&mut DecodeSession> = Vec::new();
        for s in sessions.iter_mut() {
            let slot = match &s.kv {
                KvCache::Slot(a) => Some((a.arena().clone(), a.index())),
                _ => None,
            };
            let Some((arena, idx)) = slot else {
                rest.push(&mut **s);
                continue;
            };
            // fast path: O(vocab) per session, no token re-scan
            let last = *s.tokens.last().unwrap_or(&0);
            let next = ((last + 1) as usize) % v;
            let mut row = vec![0.0f32; v];
            row[next] = 10.0;
            s.logits = row;
            lock_arena(&arena).note(idx, last, (s.tokens.len() - 1) as i32);
        }
        if !rest.is_empty() {
            decode::recompute_decode_step(self, &mut rest)?;
        }
        Ok(())
    }
}

/// Where the served model comes from.
enum Source {
    Mock,
    Checkpoint { artifacts: String, model: String, path: std::path::PathBuf },
}

fn engine_for(
    max_batch: usize,
    cache: usize,
    src: &Source,
    trace: Option<Arc<TraceCollector>>,
) -> Result<Engine> {
    let tuning = ModelTuning { max_batch, batch_window: Duration::from_millis(10) };
    let mut b = Engine::builder().cache(cache);
    if let Some(tc) = trace {
        b = b.trace(tc);
    }
    let b = match src {
        Source::Mock => b.model_with("bench", tuning, || {
            let lm: Box<dyn LanguageModel> =
                Box::new(MockLm::new(ModelConfig::builtin("nt-tiny")?));
            Ok(lm)
        }),
        Source::Checkpoint { artifacts, model, path } => {
            let (a, m, p) = (artifacts.clone(), model.clone(), path.clone());
            b.model_with("bench", tuning, move || {
                let lm: Box<dyn LanguageModel> = Box::new(ServableModel::load(&a, &m, &p)?);
                Ok(lm)
            })
        }
    };
    b.build()
}

struct RunMetrics {
    served: usize,
    rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    mean_batch: f32,
    cache_hit_rate: f64,
    prefill_tokens: u128,
    decode_tokens: u128,
    prefill_tok_per_s: f64,
    decode_tok_per_s: f64,
    /// full engine-side stats: latency histograms + failure accounting
    stats: ModelStats,
}

/// Drive one engine config with 4 client threads cycling a small prompt
/// pool (repeats exercise the response cache).
fn drive(mut engine: Engine, n_requests: usize) -> Result<RunMetrics> {
    let client = engine.start()?;
    let lat = std::sync::Mutex::new(Vec::<u128>::new());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..4 {
            let client = client.clone();
            let lat = &lat;
            s.spawn(move || {
                for i in 0..n_requests / 4 {
                    // 4-prompt pool per client over 8 iterations: the
                    // second lap repeats every prompt, exercising the cache
                    let prompt = vec![1, (8 + (c * 31 + (i % 4) * 13) % 150) as i32];
                    let t = Instant::now();
                    if client.generate("bench", GenRequest::greedy(prompt, 8)).is_ok() {
                        lat.lock().unwrap().push(t.elapsed().as_micros());
                    }
                }
            });
        }
    });
    let stats = engine.shutdown()?;
    let wall = t0.elapsed().as_secs_f64();
    let mut l = lat.into_inner().unwrap();
    l.sort_unstable();
    if l.is_empty() {
        return Err(normtweak::Error::Serve("no requests completed".into()));
    }
    let m = stats.model("bench").cloned().unwrap_or_default();
    Ok(RunMetrics {
        served: m.served,
        rps: m.served as f64 / wall,
        p50_ms: l[l.len() / 2] as f64 / 1000.0,
        p99_ms: l[(l.len() * 99 / 100).min(l.len() - 1)] as f64 / 1000.0,
        mean_batch: m.mean_batch(),
        cache_hit_rate: m.cache_hit_rate(),
        prefill_tokens: m.prefill_tokens,
        decode_tokens: m.decode_tokens,
        prefill_tok_per_s: m.prefill_tok_per_s(),
        decode_tok_per_s: m.decode_tok_per_s(),
        stats: m,
    })
}

/// Pull `--trace out.json` from argv; every other argument (cargo bench
/// passes its own) is ignored.
fn trace_arg() -> Option<String> {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter().position(|a| a == "--trace").and_then(|i| argv.get(i + 1).cloned())
}

fn main() {
    let artifacts = std::env::var("NT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let out_path =
        std::env::var("NT_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
    let trace = trace_arg().map(|path| {
        (
            Arc::new(TraceCollector::new(normtweak::obs::trace::DEFAULT_CAPACITY)),
            path,
        )
    });
    println!("== bench_serve ==");

    let (src, model_desc) = if std::path::Path::new(&artifacts).join("manifest.json").exists()
    {
        // quantize once, park the checkpoint; every engine reloads it
        let rt = Runtime::new(&artifacts).unwrap();
        let w = ModelWeights::load_from_dir("nt-tiny", &artifacts).unwrap();
        let stream = normtweak::calib::corpus::token_stream(
            &normtweak::calib::corpus::wiki_syn(),
            rt.manifest.calib_batch * w.config.seq,
        );
        let calib = CalibSet::from_stream(&stream, rt.manifest.calib_batch,
                                          w.config.seq, "wiki-syn").unwrap();
        let cfg = PipelineConfig::new("rtn", QuantScheme::w4_perchannel());
        let (qm, _) = quantize_model(&rt, &w, &calib, &cfg).unwrap();
        let path = std::env::temp_dir().join("bench_serve_rtn_w4.ntz");
        qm.save(&path).unwrap();
        (
            Source::Checkpoint { artifacts: artifacts.clone(), model: "nt-tiny".into(), path },
            "nt-tiny rtn w4".to_string(),
        )
    } else {
        normtweak::log_warn!(
            "bench_serve",
            "no artifacts at {artifacts} — benching the mock model"
        );
        (Source::Mock, "mock".to_string())
    };

    let mut configs: Vec<Json> = Vec::new();
    for max_batch in [1usize, 4, 8] {
        let tc = trace.as_ref().map(|(tc, _)| tc.clone());
        let engine = engine_for(max_batch, 32, &src, tc).unwrap();
        let m = drive(engine, 32).unwrap();
        if let Some(err) = &m.stats.first_error {
            // a lane that failed mid-run still reports aggregates; make the
            // root cause visible instead of burying it in clean-looking JSON
            normtweak::log_warn!(
                "bench_serve",
                "max_batch {max_batch}: {} request(s) failed; first error: {err}",
                m.stats.failed
            );
        }
        println!(
            "max_batch {max_batch}: {:>6.1} req/s   p50 {:>7.1} ms   p99 {:>7.1} ms   \
             mean batch {:>4.1}   cache hit rate {:.2}   \
             decode {:>7.1} tok/s   prefill {:>7.1} tok/s",
            m.rps, m.p50_ms, m.p99_ms, m.mean_batch, m.cache_hit_rate,
            m.decode_tok_per_s, m.prefill_tok_per_s
        );
        configs.push(json::obj(vec![
            ("max_batch", json::n(max_batch as f64)),
            ("served", json::n(m.served as f64)),
            ("req_per_s", json::n(m.rps)),
            ("p50_ms", json::n(m.p50_ms)),
            ("p99_ms", json::n(m.p99_ms)),
            ("mean_batch", json::n(m.mean_batch as f64)),
            ("cache_hit_rate", json::n(m.cache_hit_rate)),
            // prefill/decode split: prompt tokens pushed through prefill
            // vs tokens produced by incremental decode steps, with each
            // side's own throughput (offline mock runs the recompute
            // fallback, so the split exists there too)
            ("prefill_tokens", json::n(m.prefill_tokens as f64)),
            ("decode_tokens", json::n(m.decode_tokens as f64)),
            ("prefill_tok_per_s", json::n(m.prefill_tok_per_s)),
            ("decode_tok_per_s", json::n(m.decode_tok_per_s)),
            // engine-measured per-phase latency percentiles (µs): recorded
            // by the scheduler itself, so queue wait and decode-step cost
            // are split instead of folded into the client-side round trip;
            // phases that never ran keep their keys with count: 0
            ("latency_us", m.stats.latency_us_json()),
            // decode fast-path health: KV-arena occupancy per decode turn
            // and riders per admission round (count-zero shapes on lanes
            // without an arena)
            ("fast_path", m.stats.fast_path_json()),
            ("failed", json::n(m.stats.failed as f64)),
            (
                "first_error",
                match &m.stats.first_error {
                    Some(e) => json::s(e.clone()),
                    None => Json::Null,
                },
            ),
        ]));
    }
    let record = json::obj(vec![
        ("bench", json::s("serve")),
        ("model", json::s(model_desc)),
        ("engine", json::s("engine::Engine (multi-model scheduler)")),
        ("configs", json::arr(configs)),
    ]);
    std::fs::write(&out_path, record.emit() + "\n").unwrap();
    println!("wrote {out_path}");
    if let Some((tc, path)) = &trace {
        tc.write_chrome(
            std::path::Path::new(path),
            Some(&normtweak::obs::global().snapshot()),
        )
        .unwrap();
        println!("wrote {path}");
    }
}

//! Table-3 analog as a benchmark: full Algorithm-1 wall time, GPTQ vs
//! GPTQ+NT per model — the paper's "tweaking cost" claim (overhead < 2x).
//! Requires `make artifacts`.

use std::time::Instant;

use normtweak::calib::CalibSet;
use normtweak::coordinator::{quantize_model, PipelineConfig};
use normtweak::model::ModelWeights;
use normtweak::quant::QuantScheme;
use normtweak::runtime::Runtime;
use normtweak::tweak::TweakConfig;

fn main() {
    let artifacts = std::env::var("NT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        eprintln!("[skip] run `make artifacts` first");
        return;
    }
    println!("== bench_pipeline (Table 3: quantization runtime) ==");
    let rt = Runtime::new(&artifacts).unwrap();

    for model in ["nt-tiny", "nt-small"] {
        let Ok(w) = ModelWeights::load_from_dir(model, &artifacts) else {
            continue;
        };
        let stream = normtweak::calib::corpus::token_stream(
            &normtweak::calib::corpus::wiki_syn(),
            rt.manifest.calib_batch * w.config.seq,
        );
        let calib = CalibSet::from_stream(&stream, rt.manifest.calib_batch,
                                          w.config.seq, "wiki-syn").unwrap();

        // warm the executable cache so we time the pipeline, not compilation
        let warm = PipelineConfig::new("gptq", QuantScheme::w4_perchannel())
            .with_tweak(TweakConfig::default());
        quantize_model(&rt, &w, &calib, &warm).unwrap();

        let t0 = Instant::now();
        let cfg = PipelineConfig::new("gptq", QuantScheme::w4_perchannel());
        quantize_model(&rt, &w, &calib, &cfg).unwrap();
        let plain = t0.elapsed();

        let t1 = Instant::now();
        quantize_model(&rt, &w, &calib, &warm).unwrap();
        let tweaked = t1.elapsed();

        println!(
            "{model:<14} GPTQ {plain:>8.2?}   GPTQ+NT {tweaked:>8.2?}   overhead {:+.0}%",
            (tweaked.as_secs_f64() / plain.as_secs_f64() - 1.0) * 100.0
        );
    }
}

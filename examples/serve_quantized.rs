//! Serving example: quantize two variants of a model and host them side by
//! side on the multi-model [`normtweak::engine::Engine`] — the deployment
//! story (a norm-tweaked GPTQ build next to a plain-RTN build, the kind of
//! fleet the mixed-precision planner suggests).
//!
//! ```text
//! cargo run --release --example serve_quantized [-- nt-small [n_requests]]
//! ```

use std::time::Instant;

use normtweak::calib::CalibSet;
use normtweak::coordinator::{quantize_model, PipelineConfig};
use normtweak::engine::{Engine, GenRequest, ServableModel};
use normtweak::eval::LanguageModel;
use normtweak::model::ModelWeights;
use normtweak::quant::QuantScheme;
use normtweak::runtime::Runtime;
use normtweak::tweak::TweakConfig;

fn main() -> normtweak::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "nt-small".to_string());
    let n_requests: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);
    let artifacts = std::env::var("NT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // quantize two servable variants and park them as checkpoints; the
    // engine's factories reload them inside the scheduler thread
    let runtime = Runtime::new(&artifacts)?;
    let weights = ModelWeights::load_from_dir(&model, &artifacts)?;
    let stream = normtweak::calib::corpus::token_stream(
        &normtweak::calib::corpus::wiki_syn(),
        runtime.manifest.calib_batch * weights.config.seq,
    );
    let calib = CalibSet::from_stream(&stream, runtime.manifest.calib_batch,
                                      weights.config.seq, "wiki-syn")?;
    let tmp = std::env::temp_dir();
    let gptq_ckpt = tmp.join("serve_quantized_gptq_nt.ntz");
    let rtn_ckpt = tmp.join("serve_quantized_rtn.ntz");
    eprintln!("quantizing {model} twice for serving (gptq+NT, rtn)...");
    let cfg = PipelineConfig::new("gptq", QuantScheme::w4_perchannel())
        .with_tweak(TweakConfig::default());
    let (qm, _) = quantize_model(&runtime, &weights, &calib, &cfg)?;
    qm.save(&gptq_ckpt)?;
    let cfg = PipelineConfig::new("rtn", QuantScheme::w4_perchannel());
    let (qm, _) = quantize_model(&runtime, &weights, &calib, &cfg)?;
    qm.save(&rtn_ckpt)?;

    // register both under one engine; start() builds + warms them up
    let mut engine = Engine::builder()
        .model("gptq-nt", {
            let (a, m, c) = (artifacts.clone(), model.clone(), gptq_ckpt.clone());
            move || {
                let lm: Box<dyn LanguageModel> = Box::new(ServableModel::load(&a, &m, &c)?);
                Ok(lm)
            }
        })
        .model("rtn", {
            let (a, m, c) = (artifacts.clone(), model.clone(), rtn_ckpt.clone());
            move || {
                let lm: Box<dyn LanguageModel> = Box::new(ServableModel::load(&a, &m, &c)?);
                Ok(lm)
            }
        })
        .cache(64)
        .build()?;
    let client = engine.start()?;

    // drive concurrent traffic, alternating models per request
    let n_clients = 4;
    let latencies = std::sync::Mutex::new(Vec::<u128>::new());
    let new_tokens = std::sync::atomic::AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let client = client.clone();
            let (lat, new_tokens) = (&latencies, &new_tokens);
            s.spawn(move || {
                for i in 0..n_requests / n_clients {
                    let key = if (c + i) % 2 == 0 { "gptq-nt" } else { "rtn" };
                    let prompt = vec![1, (8 + (c * 37 + i * 11) % 480) as i32];
                    let t = Instant::now();
                    if let Ok(resp) = client.generate(key, GenRequest::greedy(prompt, 16)) {
                        lat.lock().unwrap().push(t.elapsed().as_micros());
                        // cache hits replay answered tokens but generate none
                        if !resp.cached {
                            new_tokens.fetch_add(
                                resp.new_tokens().len(),
                                std::sync::atomic::Ordering::Relaxed,
                            );
                        }
                    }
                }
            });
        }
    });
    let stats = engine.shutdown()?;
    let wall = t0.elapsed().as_secs_f64();

    let mut lat = latencies.into_inner().unwrap();
    lat.sort_unstable();
    if lat.is_empty() {
        return Err(normtweak::Error::Serve("no requests completed".into()));
    }
    let pct = |p: usize| lat[(lat.len() * p / 100).min(lat.len() - 1)] as f64 / 1000.0;
    println!("\n== serve_quantized: {model}, {} requests, {n_clients} clients, 2 models ==",
             stats.total_served());
    println!("throughput: {:.1} req/s  ({:.1} tok/s generated)",
             stats.total_served() as f64 / wall,
             new_tokens.load(std::sync::atomic::Ordering::Relaxed) as f64 / wall);
    println!("latency:    p50 {:.0} ms   p90 {:.0} ms   p99 {:.0} ms", pct(50), pct(90), pct(99));
    for (name, m) in &stats.models {
        println!(
            "{name:>8}: served {}, batches {} (mean {:.2}, max {}), \
             cache hits {}/{}, warmup batches {}",
            m.served,
            m.batches,
            m.mean_batch(),
            m.max_batch_seen,
            m.cache_hits,
            m.cache_hits + m.cache_misses,
            m.warmup_batches
        );
    }
    Ok(())
}

//! Serving example: quantize (or load) a model and serve batched traffic,
//! reporting latency percentiles and throughput — the deployment story.
//!
//! ```text
//! cargo run --release --example serve_quantized [-- nt-small [n_requests]]
//! ```

use std::time::Instant;

use normtweak::calib::CalibSet;
use normtweak::coordinator::{quantize_model, PipelineConfig, QuantModel};
use normtweak::model::ModelWeights;
use normtweak::quant::QuantScheme;
use normtweak::runtime::Runtime;
use normtweak::serve::{channel, serve_loop, ServeConfig};
use normtweak::tweak::TweakConfig;

fn main() -> normtweak::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "nt-small".to_string());
    let n_requests: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);
    let artifacts = std::env::var("NT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    let runtime = Runtime::new(&artifacts)?;
    let weights = ModelWeights::load_from_dir(&model, &artifacts)?;

    // quantize W4 + NT for serving
    let stream = normtweak::calib::corpus::token_stream(
        &normtweak::calib::corpus::wiki_syn(),
        runtime.manifest.calib_batch * weights.config.seq,
    );
    let calib = CalibSet::from_stream(&stream, runtime.manifest.calib_batch,
                                      weights.config.seq, "wiki-syn")?;
    let cfg = PipelineConfig::new("gptq", QuantScheme::w4_perchannel())
        .with_tweak(TweakConfig::default());
    eprintln!("quantizing {model} for serving...");
    let (qm, _) = quantize_model(&runtime, &weights, &calib, &cfg)?;
    let server_model = QuantModel::new(&runtime, &qm)?;

    // drive concurrent traffic
    let n_clients = 4;
    let (handle, rx) = channel();
    let latencies = std::sync::Mutex::new(Vec::<u128>::new());
    let t0 = Instant::now();
    let stats = std::thread::scope(|s| {
        for c in 0..n_clients {
            let h = handle.clone();
            let lat = &latencies;
            s.spawn(move || {
                for i in 0..n_requests / n_clients {
                    let prompt = vec![1, (8 + (c * 37 + i * 11) % 480) as i32];
                    let t = Instant::now();
                    if h.submit(prompt, 16).is_ok() {
                        lat.lock().unwrap().push(t.elapsed().as_micros());
                    }
                }
            });
        }
        drop(handle);
        serve_loop(
            &server_model,
            ServeConfig { max_batch: 8, batch_window: std::time::Duration::from_millis(10) },
            rx,
        )
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let mut lat = latencies.into_inner().unwrap();
    lat.sort_unstable();
    let pct = |p: usize| lat[(lat.len() * p / 100).min(lat.len() - 1)] as f64 / 1000.0;
    println!("\n== serve_quantized: {model}, {} requests, {n_clients} clients ==", stats.served);
    println!("throughput: {:.1} req/s  ({:.1} tok/s generated)",
             stats.served as f64 / wall,
             (stats.served * 16) as f64 / wall);
    println!("latency:    p50 {:.0} ms   p90 {:.0} ms   p99 {:.0} ms", pct(50), pct(90), pct(99));
    println!("batching:   mean {:.2}, max {} (from {} batches)",
             stats.mean_batch(), stats.max_batch_seen, stats.batches);
    Ok(())
}

//! Quickstart — the end-to-end driver (DESIGN.md §8).
//!
//! Loads the pretrained nt-small, self-generates a calibration set
//! (GenData-V2), runs GPTQ W4 with and without Norm Tweaking through the
//! PJRT runtime, and compares LAMBADA-syn accuracy + held-out PPL against
//! the float model — the full three-layer stack in one run.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use normtweak::coordinator::{build_calib, quantize_model, FloatModel, PipelineConfig,
                             QuantModel};
use normtweak::eval::{lambada, ppl};
use normtweak::model::ModelWeights;
use normtweak::quant::QuantScheme;
use normtweak::report::{f2, f4, Table};
use normtweak::runtime::Runtime;
use normtweak::tweak::TweakConfig;

fn main() -> normtweak::Result<()> {
    let artifacts = std::env::var("NT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let model = "nt-small";

    println!("== normtweak quickstart: {model} ==\n");
    let runtime = Runtime::new(&artifacts)?;
    let weights = ModelWeights::load_from_dir(model, &artifacts)?;
    println!(
        "loaded {} ({} params, {} layers, {:?})",
        model,
        weights.config.n_params(),
        weights.config.n_layer,
        weights.config.norm
    );

    // 1. calibration data: the model generates its own (GenData-V2)
    let calib = build_calib(&runtime, &weights, "gen-v2",
                            runtime.manifest.calib_batch, 0xCA11B)?;
    println!("calibration: {} samples x {} tokens ({})",
             calib.n_samples(), calib.seq(), calib.source);

    // 2. quantize: GPTQ W4, plain and with Norm Tweaking
    let scheme = QuantScheme::w4_perchannel();
    let (q_plain, m_plain) = quantize_model(
        &runtime, &weights, &calib,
        &PipelineConfig::new("gptq", scheme))?;
    let (q_nt, m_nt) = quantize_model(
        &runtime, &weights, &calib,
        &PipelineConfig::new("gptq", scheme).with_tweak(TweakConfig::default()))?;
    println!(
        "\nquantized twice: GPTQ {}s, GPTQ+NT {}s ({}x weight compression)",
        f2(m_plain.total_millis as f32 / 1000.0),
        f2(m_nt.total_millis as f32 / 1000.0),
        f2(1.0 / m_nt.compression_ratio),
    );
    q_nt.save(format!("{artifacts}/quickstart_{model}_w4nt.ntz"))?;

    // 3. evaluate all three against each other
    let fm = FloatModel::new(&runtime, &weights)?;
    let qp = QuantModel::new(&runtime, &q_plain)?;
    let qn = QuantModel::new(&runtime, &q_nt)?;

    let set = lambada::LambadaSet::standard(weights.config.seq);
    let mut t = Table::new("quickstart results", &["metric", "FP32", "GPTQ W4", "GPTQ+NT W4"]);
    t.push(vec![
        "lambada-syn acc %".into(),
        f4(lambada::accuracy(&fm, &set, 8)?),
        f4(lambada::accuracy(&qp, &set, 8)?),
        f4(lambada::accuracy(&qn, &set, 8)?),
    ]);
    t.push(vec![
        "wiki-syn ppl".into(),
        f4(ppl::perplexity(&fm, "wiki-syn", 4096, 8)?),
        f4(ppl::perplexity(&qp, "wiki-syn", 4096, 8)?),
        f4(ppl::perplexity(&qn, "wiki-syn", 4096, 8)?),
    ]);
    println!("\n{}", t.ascii());

    // 4. per-layer drift — the mechanism at work (Figure 1)
    println!("per-layer activation drift Δμ (quant vs float stream):");
    for (a, b) in m_plain.layers.iter().zip(&m_nt.layers) {
        println!(
            "  layer {}: GPTQ {:.5}  ->  NT {:.5}",
            a.layer, a.delta_mu, b.delta_mu
        );
    }
    Ok(())
}

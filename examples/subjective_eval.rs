//! Table 5 — subjective evaluation: generations from the float, GPTQ-2bit,
//! and Norm-Tweaking-2bit models on a fixed prompt, mechanically scored
//! against the corpus grammar (our grammar is checkable, so the paper's
//! human judgement becomes an exact error counter).
//!
//! ```text
//! cargo run --release --example subjective_eval [-- nt-small]
//! ```

use normtweak::report::repro::{table5, ReproCtx};

fn main() -> normtweak::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "nt-small".to_string());
    let artifacts = std::env::var("NT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let ctx = ReproCtx::new(&artifacts)?;
    let t = table5(&ctx, &model)?;
    println!("{}", t.ascii());
    Ok(())
}

//! Figure 1 — layer-by-layer activation-distribution drift of the quantized
//! stream, GPTQ vs Norm-Tweaking, written as CSV + ASCII chart.
//!
//! ```text
//! cargo run --release --example figure1_drift [-- nt-small]
//! ```

use normtweak::report::repro::{figure1, ReproCtx};

fn main() -> normtweak::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "nt-small".to_string());
    let artifacts = std::env::var("NT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let ctx = ReproCtx::new(&artifacts)?;
    let table = figure1(&ctx, &model)?;
    println!("{}", table.ascii());

    // CSV for external plotting
    let out = std::path::Path::new(&artifacts).join("experiments");
    std::fs::create_dir_all(&out)?;
    let csv_path = out.join(format!("figure1_{model}.csv"));
    let mut csv = String::from("layer,gptq_delta_mu,nt_delta_mu\n");
    for row in &table.rows {
        csv.push_str(&format!("{},{},{}\n", row[0], row[1], row[2]));
    }
    std::fs::write(&csv_path, csv)?;
    eprintln!("csv written to {}", csv_path.display());
    Ok(())
}

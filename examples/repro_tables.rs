//! Regenerate every table and figure of the paper's evaluation section.
//!
//! ```text
//! cargo run --release --example repro_tables               # quick set (nt-tiny/nt-small)
//! cargo run --release --example repro_tables -- --full     # all models incl. nt-medium
//! cargo run --release --example repro_tables -- --table 2  # one table only
//! ```
//!
//! Output: ASCII to stdout + markdown appended to artifacts/experiments/.

use normtweak::report::repro::{self, ReproCtx};
use normtweak::report::{save_record, Table};

fn main() -> normtweak::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let only: Option<String> = args
        .iter()
        .position(|a| a == "--table")
        .and_then(|i| args.get(i + 1).cloned());

    let artifacts = std::env::var("NT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let ctx = ReproCtx::new(&artifacts)?;

    // model sets per table (runtime grows with model size)
    let t2_models: Vec<&str> = if full {
        vec!["nt-tiny", "nt-small", "nt-small-rms", "nt-medium"]
    } else {
        vec!["nt-tiny", "nt-small"]
    };
    let small = ["nt-small"];
    let t9_models: Vec<&str> = if full {
        vec!["nt-small", "nt-small-rms"]
    } else {
        vec!["nt-small"]
    };

    let mut md = String::new();
    let mut emit = |t: Table| {
        println!("{}", t.ascii());
        md.push_str(&t.markdown());
        md.push('\n');
    };

    let want = |id: &str| only.as_deref().map(|o| o == id).unwrap_or(true);

    if want("1") {
        emit(repro::table1());
    }
    if want("fig1") {
        emit(repro::figure1(&ctx, "nt-small")?);
    }
    if want("2") {
        emit(repro::table2(&ctx, &t2_models)?);
    }
    if want("3") {
        emit(repro::table3(&ctx, &t2_models)?);
    }
    if want("4") {
        emit(repro::table4(&ctx, &small)?);
    }
    if want("5") {
        emit(repro::table5(&ctx, "nt-small")?);
    }
    if want("6") {
        emit(repro::table6(&ctx, "nt-small", &[1, 4, 10, 20, 50])?);
    }
    if want("7") {
        emit(repro::table7(&ctx, "nt-small", full)?);
    }
    if want("8") {
        emit(repro::table8(&ctx, "nt-small")?);
    }
    if want("9") {
        emit(repro::table9(&ctx, &t9_models)?);
    }
    if want("10") {
        emit(repro::table10(&ctx, "nt-small")?);
    }
    if want("plan") {
        emit(repro::table_plan(&ctx, "nt-small", 2.25)?);
    }

    let out_dir = std::path::Path::new(&artifacts).join("experiments");
    std::fs::create_dir_all(&out_dir)?;
    let path = out_dir.join("tables.md");
    std::fs::write(&path, &md)?;
    save_record(
        &artifacts,
        "repro_meta",
        &normtweak::util::json::obj(vec![
            ("full", normtweak::util::json::Json::Bool(full)),
            ("tables_md", normtweak::util::json::s(path.display().to_string())),
        ]),
    )?;
    eprintln!("markdown written to {}", path.display());
    Ok(())
}

"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, with
hypothesis sweeping shapes — the core numeric contract of the stack."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.channel_stats import channel_stats
from compile.kernels.norms import layernorm, rmsnorm
from compile.kernels.quant_matmul import quant_matmul
from compile.kernels.rtn import rtn_quantize

RNG = np.random.default_rng(0)


def randf(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# quant_matmul

@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([8, 32, 64]),
    k=st.sampled_from([64, 128, 256]),
    n=st.sampled_from([64, 128]),
    bits=st.sampled_from([2, 4, 8]),
    group_div=st.sampled_from([1, 2, 4]),
)
def test_quant_matmul_matches_ref(m, k, n, bits, group_div):
    group = k // group_div
    w = randf(k, n)
    codes, scales = ref.rtn_quantize(w, bits, group)
    x = randf(m, k)
    got = quant_matmul(x, codes, scales, group_size=group)
    want = ref.quant_matmul(x, codes, scales)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=1e-4)


def test_quant_matmul_rejects_straddling_groups():
    # block_k must not straddle a scale group
    w = randf(64, 64)
    codes, scales = ref.rtn_quantize(w, 4, 16)
    x = randf(8, 64)
    got = quant_matmul(x, codes, scales, group_size=16, block_k=16)
    np.testing.assert_allclose(got, ref.quant_matmul(x, codes, scales),
                               atol=2e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# rtn kernel

@settings(max_examples=10, deadline=None)
@given(
    k=st.sampled_from([64, 128, 512]),
    n=st.sampled_from([64, 128]),
    bits=st.sampled_from([2, 3, 4, 8]),
    group_div=st.sampled_from([1, 2, 8]),
)
def test_rtn_kernel_matches_ref(k, n, bits, group_div):
    group = max(k // group_div, 8)
    if k % group:
        group = k
    w = randf(k, n)
    c1, s1 = rtn_quantize(w, bits=bits, group_size=group)
    c2, s2 = ref.rtn_quantize(w, bits, group)
    np.testing.assert_allclose(s1, s2, rtol=1e-5)
    # XLA may fuse the two paths differently; a last-ulp scale difference can
    # flip a code sitting exactly on a rounding boundary — allow a tiny
    # fraction of off-by-one codes, nothing more.
    diff = np.abs(np.asarray(c1, dtype=np.int32) - np.asarray(c2, dtype=np.int32))
    assert diff.max() <= 1
    assert (diff > 0).mean() < 1e-3


def test_rtn_error_bound():
    w = randf(128, 64)
    c, s = rtn_quantize(w, bits=4, group_size=128)
    deq = ref.dequantize(c, s)
    err = np.abs(np.asarray(w) - np.asarray(deq))
    bound = np.asarray(s)[0][None, :] / 2 + 1e-6
    assert (err <= bound).all()


# ---------------------------------------------------------------------------
# channel stats

@settings(max_examples=10, deadline=None)
@given(
    rows=st.sampled_from([16, 100, 256, 1000]),
    c=st.sampled_from([32, 128, 384]),
)
def test_channel_stats_matches_ref(rows, c):
    x = randf(rows, c)
    mu, var = channel_stats(x)
    mu_r, var_r = ref.channel_stats(x)
    np.testing.assert_allclose(mu, mu_r, atol=1e-5)
    np.testing.assert_allclose(var, var_r, atol=1e-4)


def test_channel_stats_3d_input():
    x = randf(4, 32, 64)
    mu, var = channel_stats(x)
    mu_r, var_r = ref.channel_stats(x)
    np.testing.assert_allclose(mu, mu_r, atol=1e-5)
    np.testing.assert_allclose(var, var_r, atol=1e-4)


def test_channel_stats_padding_correct():
    # rows deliberately not a multiple of the stripe
    x = randf(257, 16)
    mu, var = channel_stats(x, block_rows=64)
    mu_r, var_r = ref.channel_stats(x)
    np.testing.assert_allclose(mu, mu_r, atol=1e-5)
    np.testing.assert_allclose(var, var_r, atol=1e-4)


# ---------------------------------------------------------------------------
# norms

@settings(max_examples=10, deadline=None)
@given(
    rows=st.sampled_from([8, 64, 200]),
    c=st.sampled_from([64, 128, 384]),
)
def test_layernorm_matches_ref(rows, c):
    x = randf(rows, c)
    g = randf(c)
    b = randf(c)
    np.testing.assert_allclose(layernorm(x, g, b), ref.layernorm(x, g, b),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.sampled_from([8, 64, 200]),
    c=st.sampled_from([64, 128, 384]),
)
def test_rmsnorm_matches_ref(rows, c):
    x = randf(rows, c)
    g = randf(c)
    np.testing.assert_allclose(rmsnorm(x, g), ref.rmsnorm(x, g),
                               atol=1e-4, rtol=1e-4)


def test_norms_3d():
    x = randf(2, 17, 96)
    g = randf(96)
    b = randf(96)
    np.testing.assert_allclose(layernorm(x, g, b), ref.layernorm(x, g, b),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# attention

@settings(max_examples=8, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([2, 4]),
    s=st.sampled_from([64, 128]),
    dh=st.sampled_from([16, 32, 64]),
)
def test_attention_matches_ref(b, h, s, dh):
    q = randf(b, h, s, dh)
    k = randf(b, h, s, dh)
    v = randf(b, h, s, dh)
    got = attention(q, k, v)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=1e-3)


def test_attention_is_causal():
    # future tokens must not influence earlier outputs
    b, h, s, dh = 1, 2, 64, 16
    q, k, v = randf(b, h, s, dh), randf(b, h, s, dh), randf(b, h, s, dh)
    out1 = np.asarray(attention(q, k, v))
    k2 = k.at[:, :, -1, :].set(99.0)
    v2 = v.at[:, :, -1, :].set(-99.0)
    out2 = np.asarray(attention(q, k2, v2))
    np.testing.assert_allclose(out1[:, :, :-1], out2[:, :, :-1], atol=1e-5)
    assert np.abs(out1[:, :, -1] - out2[:, :, -1]).max() > 1e-3


def test_attention_blocked_equals_unblocked():
    b, h, s, dh = 1, 2, 128, 32
    q, k, v = randf(b, h, s, dh), randf(b, h, s, dh), randf(b, h, s, dh)
    a = attention(q, k, v, block_q=32, block_k=32)
    bfull = attention(q, k, v, block_q=128, block_k=128)
    np.testing.assert_allclose(a, bfull, atol=2e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# dist loss oracle sanity (mirrors rust tweak::loss tests)

def test_dist_loss_zero_iff_stats_match():
    x = randf(64, 32)
    mu, var = ref.channel_stats(x)
    assert float(ref.dist_loss(mu, var, mu, var)) == 0.0
    assert float(ref.dist_loss(mu, var, mu + 0.5, var)) == pytest.approx(0.5, abs=1e-5)

"""Corpus generator: determinism, distributional properties, and the
Table-1 mismatch the GenData-V2 scheme exploits."""

import numpy as np
import pytest

from compile.configs import LANGS, VOCAB_SIZE, BOS, EOS, PERIOD
from compile.corpus import (C4_SYN, PTB_SYN, TRAIN_SPEC, WIKI_SYN, SplitMix64,
                            lambada_syn, mix64, pick_lang, recall_sequence,
                            sentence, successor, token_stream)


def test_splitmix_reference_values():
    # lock the PRNG: these values must match rust/src/calib/rng.rs
    r = SplitMix64(0)
    first = [r.next_u64() for _ in range(3)]
    r2 = SplitMix64(0)
    assert [r2.next_u64() for _ in range(3)] == first
    assert all(0 <= v < 2 ** 64 for v in first)
    assert len(set(first)) == 3


def test_mix64_is_stable():
    assert mix64(42) == mix64(42)
    assert mix64(42) != mix64(43)


def test_langs_cover_vocab():
    assert LANGS[0].lo == 8
    for a, b in zip(LANGS, LANGS[1:]):
        assert a.hi == b.lo
    assert LANGS[-1].hi == VOCAB_SIZE


def test_table1_mismatch():
    corpus5 = sum(l.corpus_share for l in LANGS[:5])
    vocab5 = sum(l.hi - l.lo for l in LANGS[:5]) / VOCAB_SIZE
    assert corpus5 > 0.7
    assert vocab5 < 0.3


def test_stream_deterministic_and_in_range():
    a = token_stream(TRAIN_SPEC, 5000)
    b = token_stream(TRAIN_SPEC, 5000)
    assert a == b
    assert all(0 <= t < VOCAB_SIZE for t in a)


def test_specs_differ():
    streams = [token_stream(s, 2000) for s in (TRAIN_SPEC, WIKI_SYN, PTB_SYN, C4_SYN)]
    for i in range(len(streams)):
        for j in range(i + 1, len(streams)):
            assert streams[i] != streams[j]


def test_corpus_share_realized():
    toks = np.array(token_stream(TRAIN_SPEC, 100_000))
    en = ((toks >= 8) & (toks < 168)).sum()
    content = (toks >= 8).sum()
    share = en / content
    assert 0.3 < share < 0.5, share  # configured 0.40


def test_sentence_follows_grammar():
    rng = SplitMix64(3)
    lang = LANGS[0]
    hits = 0
    total = 0
    for _ in range(200):
        s = sentence(rng, lang)
        assert s[-1] == PERIOD
        for a, b in zip(s[:-2], s[1:-1]):
            total += 1
            if successor(a, lang) == b:
                hits += 1
    assert 0.75 < hits / total < 0.95  # 85% designed determinism


def test_recall_sequence_layout():
    rng = SplitMix64(4)
    s = recall_sequence(rng, LANGS[1])
    assert s[0] == BOS
    assert s[-1] == EOS
    # answer (index -2) equals the value bound to the queried key (index -3)
    k_r = s[-3]
    vals = {s[1]: s[2], s[4]: s[5]}
    assert s[-2] == vals[k_r]


def test_lambada_syn_is_successor_cloze():
    items, pos = lambada_syn(9, 32, 128)
    for item, p in zip(items, pos):
        prev, ans = item[p - 1], item[p]
        lang = next(l for l in LANGS if l.lo <= prev < l.hi)
        assert ans == successor(prev, lang)
        assert all(t == 0 for t in item[p + 1:])  # padding after the answer


def test_pick_lang_respects_weights():
    rng = SplitMix64(11)
    weights = [0.0] * len(LANGS)
    weights[2] = 1.0  # all mass on fr
    for _ in range(100):
        assert pick_lang(rng, weights).name == "fr"


def test_wiki_en_heavy():
    toks = np.array(token_stream(WIKI_SYN, 30_000))
    en = ((toks >= 8) & (toks < 168)).sum()
    content = (toks >= 8).sum()
    assert en / content > 0.55

"""AOT export contract: graph inventory, HLO-text validity, manifest
consistency (fast checks on nt-tiny only — the full export is `make
artifacts`)."""

import json
import re

import pytest

from compile import aot
from compile.configs import MODELS


@pytest.fixture(scope="module")
def tiny_graphs():
    cfg = MODELS["nt-tiny"]
    return list(aot.graph_defs(cfg))


def test_graph_inventory(tiny_graphs):
    names = [g[0] for g in tiny_graphs]
    # the paper's grains plus the sweep neighbours must stay exported;
    # additions (the documented one-GROUPS-entry recipe) are fine
    assert {"pc", "g32", "g64", "g128"} <= set(aot.GROUPS)
    for b in aot.EXPORT_BUCKETS:
        assert f"embed.b{b}" in names
        assert f"block_fwd.b{b}" in names
        assert f"head.b{b}" in names
        for grp in aot.GROUPS:
            assert f"block_fwd_q.{grp}.b{b}" in names
    assert "block_taps.b32" in names
    assert "channel_stats.b32" in names
    for grp in aot.GROUPS:
        assert f"tweak_step.{grp}" in names
    assert "xtx.k128" in names and "xtx.k512" in names


def test_decode_graph_inventory(tiny_graphs):
    names = [g[0] for g in tiny_graphs]
    for b in aot.EXPORT_BUCKETS:
        assert f"block_fwd_kv.b{b}" in names
        assert f"embed_dec.b{b}" in names
        assert f"head_dec.b{b}" in names
        assert f"block_dec.b{b}" in names
        for grp in aot.GROUPS:
            assert f"block_fwd_q_kv.{grp}.b{b}" in names
            assert f"block_dec_q.{grp}.b{b}" in names


def test_decode_opt_out_drops_every_decode_graph():
    names = [g[0] for g in aot.graph_defs(MODELS["nt-tiny"], decode=False)]
    assert not any(
        n.split(".")[0] in ("block_fwd_kv", "block_fwd_q_kv", "embed_dec",
                            "head_dec", "block_dec", "block_dec_q")
        for n in names)
    # the classic inventory is untouched by the opt-out
    assert "block_fwd.b8" in names and "tweak_step.pc" in names


def test_decode_step_arg_shapes(tiny_graphs):
    by_name = {g[0]: g for g in tiny_graphs}
    cfg = MODELS["nt-tiny"]
    args = {a["name"]: a for a in by_name["block_dec.b8"][2]}
    # caches are [B, H, S, Dh] and ride last (carried-state convention)
    cache_shape = [8, cfg.n_head, cfg.seq, cfg.d_head]
    assert args["k_cache"]["shape"] == cache_shape
    assert args["v_cache"]["shape"] == cache_shape
    assert [a["name"] for a in by_name["block_dec.b8"][2]][-2:] == \
        ["k_cache", "v_cache"]
    assert args["x"]["shape"] == [8, 1, cfg.d_model]
    assert args["pos"] == {"name": "pos", "shape": [8], "dtype": "i32"}
    # one-token embed takes per-row positions too
    dec_embed = {a["name"]: a for a in by_name["embed_dec.b8"][2]}
    assert dec_embed["tokens"]["shape"] == [8, 1]
    assert dec_embed["pos"]["dtype"] == "i32"


def test_graph_defs_honours_group_subset():
    cfg = MODELS["nt-tiny"]
    names = [g[0] for g in aot.graph_defs(cfg, {"g64": 64})]
    assert "block_fwd_q.g64.b8" in names and "tweak_step.g64" in names
    assert not any(".pc" in n or ".g32" in n or ".g128" in n for n in names)
    # the pc-only ablation graphs are gated on pc actually being exported
    small = [g[0] for g in aot.graph_defs(MODELS["nt-small"], {"g64": 64})]
    assert "tweak_step_mse.pc" not in small


def test_parse_groups_strict():
    assert aot.parse_groups("pc,g32, g128") == {"pc": 0, "g32": 32,
                                                "g128": 128}
    # canonicalized: the runtime only ever derives `g{size}` spellings
    assert aot.parse_groups("g064") == {"g64": 64}
    with pytest.raises(ValueError):
        aot.parse_groups("g0")
    with pytest.raises(ValueError):
        aot.parse_groups("grain64")
    with pytest.raises(ValueError):
        aot.parse_groups("")


def test_check_groups_rejects_nondividing_grain():
    with pytest.raises(ValueError, match="does not divide"):
        aot.check_groups(MODELS["nt-tiny"], {"g48": 48})  # 128 % 48 != 0
    with pytest.raises(ValueError, match="does not divide"):
        list(aot.graph_defs(MODELS["nt-tiny"], {"g256": 256}))  # > d_model


def test_tweak_ablation_graphs_only_for_small():
    small = [g[0] for g in aot.graph_defs(MODELS["nt-small"])]
    tiny = [g[0] for g in aot.graph_defs(MODELS["nt-tiny"])]
    assert "tweak_step_mse.pc" in small and "tweak_step_kl.pc" in small
    assert "tweak_step_mse.pc" not in tiny


def test_arg_counts(tiny_graphs):
    by_name = {g[0]: g for g in tiny_graphs}
    # layernorm block: x + 12 float weights
    assert len(by_name["block_fwd.b8"][2]) == 13
    # quant block: x + 16 qweights
    assert len(by_name["block_fwd_q.pc.b8"][2]) == 17
    # tweak: x + 16 qweights + 4 m + 4 v + mu + var + lr + t
    assert len(by_name["tweak_step.pc"][2]) == 1 + 16 + 8 + 4


def test_rms_arg_counts():
    by_name = {g[0]: g for g in aot.graph_defs(MODELS["nt-small-rms"])}
    assert len(by_name["block_fwd.b8"][2]) == 11
    assert len(by_name["block_fwd_q.pc.b8"][2]) == 15
    assert len(by_name["tweak_step.pc"][2]) == 1 + 14 + 4 + 4


def test_scales_shapes_differ_by_group(tiny_graphs):
    by_name = {g[0]: g for g in tiny_graphs}

    def scales(grp, name):
        args = {a["name"]: a for a in by_name[f"block_fwd_q.{grp}.b8"][2]}
        return args[name]["shape"]

    assert scales("pc", "attn.wqkv.scales") == [1, 384]
    assert scales("g32", "attn.wqkv.scales") == [4, 384]   # 128/32
    assert scales("g64", "attn.wqkv.scales") == [2, 384]   # 128/64
    assert scales("g128", "attn.wqkv.scales") == [1, 384]  # 128/128
    assert scales("g32", "mlp.wfc2.scales") == [16, 128]   # 512/32
    pc = {a["name"]: a for a in by_name["block_fwd_q.pc.b8"][2]}
    assert pc["attn.wqkv.codes"]["dtype"] == "i8"


def test_one_graph_lowers_to_parseable_hlo():
    cfg = MODELS["nt-tiny"]
    for name, fn, in_args in aot.graph_defs(cfg):
        if name == "channel_stats.b32":
            text = aot.to_hlo_text(fn, in_args)
            assert "HloModule" in text
            assert "ENTRY" in text
            return
    pytest.fail("channel_stats graph missing")


# HLO element type -> manifest dtype spelling (inverse of aot._MANIFEST_DTYPE
# composed with the numpy->HLO naming; mirrors analysis/hlo.rs `SigDType`)
_HLO_TO_MANIFEST = {"f32": "f32", "s8": "i8", "u8": "u8",
                    "s32": "i32", "s64": "i64"}


def _parse_entry_layout(text):
    """(params, results) of the `entry_computation_layout={...}` header as
    (dtype, shape) pairs in manifest spelling — the same grammar the Rust
    `graphs` lint parses (rust/src/analysis/hlo.rs)."""
    start = text.index("entry_computation_layout=")
    i = text.index("{", start)
    depth = 0
    for j in range(i, len(text)):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                break
    body = text[i + 1:j]

    depth, arrow = 0, None
    for k, c in enumerate(body):
        if c in "({[":
            depth += 1
        elif c in ")}]":
            depth -= 1
        elif depth == 0 and body[k:k + 2] == "->":
            arrow = k
            break
    assert arrow is not None, body

    def side(s):
        s = s.strip()
        if s.startswith("("):
            s = s[1:-1]
        toks, depth, cur = [], 0, ""
        for c in s:
            if c in "({[":
                depth += 1
            elif c in ")}]":
                depth -= 1
            if c == "," and depth == 0:
                toks.append(cur)
                cur = ""
            else:
                cur += c
        if cur.strip():
            toks.append(cur)
        out = []
        for t in toks:
            m = re.match(r"(\w+)\[([\d,]*)\]", t.strip())
            assert m, t
            dims = [int(d) for d in m.group(2).split(",") if d]
            out.append((_HLO_TO_MANIFEST[m.group(1)], dims))
        return out

    return side(body[:arrow]), side(body[arrow + 2:])


def test_recorded_signatures_match_lowered_hlo(tiny_graphs):
    # the `outputs` the exporter records (jax.eval_shape intent) must agree
    # with the lowered HLO's actual ENTRY signature — the invariant the
    # Rust NT0502 lint enforces over every artifact tree; pinned here at
    # the source for two cheap-to-lower graphs (one mixed-dtype single
    # result, one multi-result)
    by_name = {g[0]: g for g in tiny_graphs}
    for name in ("embed.b8", "channel_stats.b32"):
        _, fn, in_args = by_name[name]
        recorded_in = [(a["dtype"], a["shape"]) for a in in_args]
        recorded_out = [(a["dtype"], a["shape"])
                        for a in aot.output_specs(fn, in_args)]
        params, results = _parse_entry_layout(aot.to_hlo_text(fn, in_args))
        assert params == recorded_in, name
        assert results == recorded_out, name


def test_manifest_matches_exports(tmp_path):
    # export just nt-tiny (pc + g32 via the CLI override) and verify
    # manifest ↔ files plus the schema the Rust runtime parses
    import subprocess
    import sys
    out = str(tmp_path)
    aot.main.__globals__  # keep linters quiet
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", out,
         "--models", "nt-tiny", "--groups", "pc,g32"],
        check=True,
        cwd=str(__import__("pathlib").Path(__file__).parent.parent),
    )
    manifest = json.load(open(f"{out}/manifest.json"))
    assert manifest["format"] == 1
    assert "nt-tiny" in manifest["models"]
    assert all(isinstance(b, int) and b > 0 for b in manifest["buckets"])
    # the exported-grain record the runtime validates schemes against
    assert manifest["groups"] == {"pc": 0, "g32": 32}
    names = [g["name"] for g in manifest["graphs"]]
    assert "tweak_step.g32" in names and "block_fwd_q.g32.b8" in names
    assert not any(".g64" in n or ".g128" in n for n in names)
    for g in manifest["graphs"]:
        assert (tmp_path / g["file"]).exists(), g["file"]
        # every grain-specialized graph's tag must be a manifest-level grain
        parts = g["name"].split(".")
        if parts[0] in ("block_fwd_q", "tweak_step",
                        "block_fwd_q_kv", "block_dec_q"):
            assert parts[1] in manifest["groups"], g["name"]
        for a in g["inputs"]:
            assert a["dtype"] in ("f32", "i8", "i32")
            assert all(d > 0 for d in a["shape"])

    # the decode record the Rust runtime parses: step-graph buckets plus the
    # per-layer cache shape [n_head, seq, d_head] for every exported model,
    # each bucket backed by actual step graphs on disk
    cfg = MODELS["nt-tiny"]
    dec = manifest["decode"]
    assert dec["buckets"] == manifest["buckets"]
    # the slot arena is sized to the largest decode bucket, which is by
    # construction an exported step-graph batch
    assert dec["slots"] == max(dec["buckets"])
    assert dec["caches"]["nt-tiny"] == {
        "n_layer": cfg.n_layer,
        "shape": [cfg.n_head, cfg.seq, cfg.d_head],
    }
    for b in dec["buckets"]:
        for n in (f"embed_dec.b{b}", f"head_dec.b{b}", f"block_dec.b{b}",
                  f"block_fwd_kv.b{b}", f"block_dec_q.g32.b{b}"):
            assert n in names, n
    # a cache entry without step graphs (or vice versa) is schema drift
    for g in manifest["graphs"]:
        if g["name"].startswith("block_dec"):
            assert g["model"] in dec["caches"]


def test_no_decode_export_omits_record(tmp_path):
    import subprocess
    import sys
    out = str(tmp_path)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", out,
         "--models", "nt-tiny", "--groups", "pc", "--no-decode"],
        check=True,
        cwd=str(__import__("pathlib").Path(__file__).parent.parent),
    )
    manifest = json.load(open(f"{out}/manifest.json"))
    # absent record == feature unavailable: the runtime must fall back to
    # full-context recompute, never crash
    assert "decode" not in manifest
    assert not any("dec" in g["name"] or "_kv" in g["name"]
                   for g in manifest["graphs"])

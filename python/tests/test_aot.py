"""AOT export contract: graph inventory, HLO-text validity, manifest
consistency (fast checks on nt-tiny only — the full export is `make
artifacts`)."""

import json

import pytest

from compile import aot
from compile.configs import MODELS


@pytest.fixture(scope="module")
def tiny_graphs():
    cfg = MODELS["nt-tiny"]
    return list(aot.graph_defs(cfg))


def test_graph_inventory(tiny_graphs):
    names = [g[0] for g in tiny_graphs]
    for b in aot.EXPORT_BUCKETS:
        assert f"embed.b{b}" in names
        assert f"block_fwd.b{b}" in names
        assert f"head.b{b}" in names
        for grp in aot.GROUPS:
            assert f"block_fwd_q.{grp}.b{b}" in names
    assert "block_taps.b32" in names
    assert "channel_stats.b32" in names
    assert "tweak_step.pc" in names
    assert "tweak_step.g64" in names
    assert "xtx.k128" in names and "xtx.k512" in names


def test_tweak_ablation_graphs_only_for_small():
    small = [g[0] for g in aot.graph_defs(MODELS["nt-small"])]
    tiny = [g[0] for g in aot.graph_defs(MODELS["nt-tiny"])]
    assert "tweak_step_mse.pc" in small and "tweak_step_kl.pc" in small
    assert "tweak_step_mse.pc" not in tiny


def test_arg_counts(tiny_graphs):
    by_name = {g[0]: g for g in tiny_graphs}
    # layernorm block: x + 12 float weights
    assert len(by_name["block_fwd.b8"][2]) == 13
    # quant block: x + 16 qweights
    assert len(by_name["block_fwd_q.pc.b8"][2]) == 17
    # tweak: x + 16 qweights + 4 m + 4 v + mu + var + lr + t
    assert len(by_name["tweak_step.pc"][2]) == 1 + 16 + 8 + 4


def test_rms_arg_counts():
    by_name = {g[0]: g for g in aot.graph_defs(MODELS["nt-small-rms"])}
    assert len(by_name["block_fwd.b8"][2]) == 11
    assert len(by_name["block_fwd_q.pc.b8"][2]) == 15
    assert len(by_name["tweak_step.pc"][2]) == 1 + 14 + 4 + 4


def test_scales_shapes_differ_by_group(tiny_graphs):
    by_name = {g[0]: g for g in tiny_graphs}
    pc = {a["name"]: a for a in by_name["block_fwd_q.pc.b8"][2]}
    g64 = {a["name"]: a for a in by_name["block_fwd_q.g64.b8"][2]}
    assert pc["attn.wqkv.scales"]["shape"] == [1, 384]
    assert g64["attn.wqkv.scales"]["shape"] == [2, 384]  # 128/64
    assert pc["attn.wqkv.codes"]["dtype"] == "i8"


def test_one_graph_lowers_to_parseable_hlo():
    cfg = MODELS["nt-tiny"]
    for name, fn, in_args in aot.graph_defs(cfg):
        if name == "channel_stats.b32":
            text = aot.to_hlo_text(fn, in_args)
            assert "HloModule" in text
            assert "ENTRY" in text
            return
    pytest.fail("channel_stats graph missing")


def test_manifest_matches_exports(tmp_path):
    # export just nt-tiny and verify manifest ↔ files
    import subprocess
    import sys
    out = str(tmp_path)
    aot.main.__globals__  # keep linters quiet
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", out, "--models", "nt-tiny"],
        check=True,
        cwd=str(__import__("pathlib").Path(__file__).parent.parent),
    )
    manifest = json.load(open(f"{out}/manifest.json"))
    assert manifest["format"] == 1
    assert "nt-tiny" in manifest["models"]
    for g in manifest["graphs"]:
        assert (tmp_path / g["file"]).exists(), g["file"]
        for a in g["inputs"]:
            assert a["dtype"] in ("f32", "i8", "i32")
            assert all(d > 0 for d in a["shape"])

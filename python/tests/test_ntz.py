"""The .ntz archive format (python side; the Rust side has its own
round-trip tests, and corpus_crosscheck.rs proves cross-language reads)."""

import numpy as np
import pytest

from compile import ntz


def test_roundtrip(tmp_path):
    path = str(tmp_path / "t.ntz")
    tensors = {
        "f": np.random.default_rng(0).normal(size=(3, 4)).astype(np.float32),
        "i8": np.array([-128, 0, 127], dtype=np.int8),
        "u8": np.array([0, 255], dtype=np.uint8),
        "i32": np.array([[1, -1]], dtype=np.int32),
        "i64": np.array([2 ** 40], dtype=np.int64),
    }
    ntz.save(path, tensors)
    back = ntz.load(path)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_f64_downcast(tmp_path):
    path = str(tmp_path / "t.ntz")
    ntz.save(path, {"x": np.array([1.5], dtype=np.float64)})
    assert ntz.load(path)["x"].dtype == np.float32


def test_single_and_empty(tmp_path):
    # the stack uses rank>=1 tensors only (scalars travel as shape [1])
    path = str(tmp_path / "t.ntz")
    ntz.save(path, {"s": np.array([3.5], dtype=np.float32),
                    "e": np.zeros((0,), dtype=np.float32)})
    back = ntz.load(path)
    assert back["s"].shape == (1,)
    assert float(back["s"][0]) == 3.5
    assert back["e"].shape == (0,)


def test_bad_magic(tmp_path):
    path = tmp_path / "bad.ntz"
    path.write_bytes(b"JUNKxxxx")
    with pytest.raises(AssertionError):
        ntz.load(str(path))

"""L2 correctness: block/model forwards, pallas≡oracle paths, quantized
blocks, and the fused tweak_step (gradient direction + Adam arithmetic)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import MODELS
from compile.kernels import ref

CFG = MODELS["nt-tiny"]
RMS = MODELS["nt-small-rms"]
RNG = np.random.default_rng(7)


def randf(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


def flat_weights(cfg, params, i=0):
    p = f"block{i}."
    if cfg.norm == "layernorm":
        names = ("ln1.g", "ln1.b", "attn.wqkv", "attn.bqkv", "attn.wproj",
                 "attn.bproj", "ln2.g", "ln2.b", "mlp.wfc1", "mlp.bfc1",
                 "mlp.wfc2", "mlp.bfc2")
    else:
        names = ("ln1.g", "attn.wqkv", "attn.bqkv", "attn.wproj",
                 "attn.bproj", "ln2.g", "mlp.wfc1", "mlp.bfc1",
                 "mlp.wfc2", "mlp.bfc2")
    return [params[p + n] for n in names]


def quantize_flat(cfg, flat, bits=4):
    d = cfg.d_model
    if cfg.norm == "layernorm":
        (g1, b1, wqkv, bqkv, wproj, bproj, g2, b2, wfc1, bfc1, wfc2, bfc2) = flat
    else:
        (g1, wqkv, bqkv, wproj, bproj, g2, wfc1, bfc1, wfc2, bfc2) = flat
        b1 = b2 = None
    cq, sq = ref.rtn_quantize(wqkv, bits, d)
    cp, sp = ref.rtn_quantize(wproj, bits, d)
    c1, s1 = ref.rtn_quantize(wfc1, bits, d)
    c2, s2 = ref.rtn_quantize(wfc2, bits, cfg.d_ff)
    if cfg.norm == "layernorm":
        return [g1, b1, cq, sq, bqkv, cp, sp, bproj, g2, b2, c1, s1, bfc1,
                c2, s2, bfc2]
    return [g1, cq, sq, bqkv, cp, sp, bproj, g2, c1, s1, bfc1, c2, s2, bfc2]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, 0)


@pytest.fixture(scope="module")
def params_rms():
    return M.init_params(RMS, 0)


def test_init_params_registry(params):
    assert set(params.keys()) == set(CFG.param_names())
    assert params["tok_emb"].shape == (CFG.vocab, CFG.d_model)


def test_block_fwd_pallas_equals_ref(params):
    flat = flat_weights(CFG, params)
    x = randf(2, CFG.seq, CFG.d_model)
    a = M.block_fwd(CFG, x, flat, use_pallas=False)
    b = M.block_fwd(CFG, x, flat, use_pallas=True)
    np.testing.assert_allclose(a, b, atol=2e-4, rtol=1e-3)


def test_block_fwd_q_pallas_equals_ref(params):
    qflat = quantize_flat(CFG, flat_weights(CFG, params))
    x = randf(2, CFG.seq, CFG.d_model)
    a = M.block_fwd_q(CFG, x, qflat, use_pallas=False)
    b = M.block_fwd_q(CFG, x, qflat, use_pallas=True)
    np.testing.assert_allclose(a, b, atol=2e-4, rtol=1e-3)


def test_rms_model_block(params_rms):
    flat = flat_weights(RMS, params_rms)
    assert len(flat) == 10
    x = randf(1, RMS.seq, RMS.d_model)
    y = M.block_fwd(RMS, x, flat, use_pallas=False)
    assert y.shape == x.shape
    qflat = quantize_flat(RMS, flat)
    yq = M.block_fwd_q(RMS, x, qflat, use_pallas=False)
    assert yq.shape == x.shape
    # quantization error is present but bounded
    assert 0 < float(jnp.abs(y - yq).max()) < 10.0


def test_taps_shapes_and_first_tap_is_ln1(params):
    flat = flat_weights(CFG, params)
    x = randf(2, CFG.seq, CFG.d_model)
    t_qkv, t_proj, t_fc1, t_fc2 = M.block_taps(CFG, x, flat, use_pallas=False)
    assert t_fc2.shape == (2, CFG.seq, CFG.d_ff)
    expect = ref.layernorm(x, flat[0], flat[1])
    np.testing.assert_allclose(t_qkv, expect, atol=1e-5)


def test_head_and_embed(params):
    toks = jnp.asarray(RNG.integers(0, CFG.vocab, size=(2, CFG.seq)), dtype=jnp.int32)
    x = M.embed(CFG, toks, params["tok_emb"], params["pos_emb"])
    assert x.shape == (2, CFG.seq, CFG.d_model)
    logits = M.head(CFG, x, [params["lnf.g"], params["lnf.b"]],
                    params["tok_emb"], use_pallas=False)
    assert logits.shape == (2, CFG.seq, CFG.vocab)


def test_model_fwd_composes(params):
    """embed -> blocks -> head composed by hand equals model_fwd."""
    toks = jnp.asarray(RNG.integers(0, CFG.vocab, size=(1, CFG.seq)), dtype=jnp.int32)
    want = M.model_fwd(CFG, toks, params, use_pallas=False)
    x = M.embed(CFG, toks, params["tok_emb"], params["pos_emb"])
    for i in range(CFG.n_layer):
        x = M.block_fwd(CFG, x, flat_weights(CFG, params, i), use_pallas=False)
    got = M.head(CFG, x, [params["lnf.g"], params["lnf.b"]],
                 params["tok_emb"], use_pallas=False)
    np.testing.assert_allclose(got, want, atol=1e-5)


# ---------------------------------------------------------------------------
# tweak_step

def tweak_setup(params):
    flat = flat_weights(CFG, params)
    qflat = quantize_flat(CFG, flat, bits=2)
    x = randf(2, CFG.seq, CFG.d_model)
    y_f = M.block_fwd(CFG, x, flat, use_pallas=False)
    mu_f, var_f = ref.channel_stats(y_f)
    d = CFG.d_model
    m0 = [jnp.zeros(d)] * 4
    v0 = [jnp.zeros(d)] * 4
    return flat, qflat, x, y_f, mu_f, var_f, m0, v0


def test_tweak_step_reduces_loss(params):
    _, qflat, x, _, mu_f, var_f, m, v = tweak_setup(params)
    qf = list(qflat)
    losses = []
    t = 1.0
    for _ in range(6):
        out = M.tweak_step(CFG, x, qf, m, v, mu_f, var_f,
                           jnp.asarray([2e-3]), jnp.asarray([t]))
        th = out[:4]
        m = list(out[4:8])
        v = list(out[8:12])
        losses.append(float(out[-1][0]))
        qf[0], qf[1], qf[8], qf[9] = th
        t += 1
    assert losses[-1] < losses[0], losses


def test_tweak_step_only_norm_params_change(params):
    _, qflat, x, _, mu_f, var_f, m, v = tweak_setup(params)
    out = M.tweak_step(CFG, x, qflat, m, v, mu_f, var_f,
                       jnp.asarray([1e-3]), jnp.asarray([1.0]))
    # outputs: 4 thetas + 4 m + 4 v + loss — codes/scales are not returned,
    # i.e. frozen by construction (Algorithm 1 line 10)
    assert len(out) == 13
    for th, orig in zip(out[:4], (qflat[0], qflat[1], qflat[8], qflat[9])):
        assert th.shape == orig.shape
        assert float(jnp.abs(th - orig).max()) > 0  # something moved


def test_tweak_step_adam_matches_manual(params):
    """One step with beta-corrected Adam must equal the hand formula."""
    _, qflat, x, _, mu_f, var_f, m, v = tweak_setup(params)
    lr = 1e-3

    def loss_fn(theta):
        qf = list(qflat)
        qf[0], qf[1], qf[8], qf[9] = theta
        y = M.block_fwd_q(CFG, x, qf, use_pallas=False)
        mu_q, var_q = ref.channel_stats(y)
        return ref.dist_loss(mu_f, var_f, mu_q, var_q)

    theta0 = [qflat[0], qflat[1], qflat[8], qflat[9]]
    grads = jax.grad(loss_fn)(theta0)
    out = M.tweak_step(CFG, x, qflat, m, v, mu_f, var_f,
                       jnp.asarray([lr]), jnp.asarray([1.0]))
    for th0, g, th1 in zip(theta0, grads, out[:4]):
        m1 = 0.1 * g
        v1 = 0.001 * g * g
        mhat = m1 / (1 - 0.9)
        vhat = v1 / (1 - 0.999)
        want = th0 - lr * mhat / (jnp.sqrt(vhat) + 1e-8)
        np.testing.assert_allclose(th1, want, atol=1e-5, rtol=1e-4)


def test_tweak_step_mse_and_kl_variants(params):
    _, qflat, x, y_f, _, _, m, v = tweak_setup(params)
    for fn in (M.tweak_step_mse, M.tweak_step_kl):
        out = fn(CFG, x, qflat, m, v, y_f, jnp.asarray([1e-3]), jnp.asarray([1.0]))
        assert len(out) == 13
        assert float(out[-1][0]) > 0.0

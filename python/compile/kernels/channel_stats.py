"""Pallas per-channel mean/variance kernel — the reduction inside L_dist.

Grid steps stripe the row dimension; each step reduces a (block_rows, C)
stripe on the VPU and accumulates sum / sum-of-squares into revisited VMEM
accumulators (the TPU analog of a CUDA blockwise shared-memory reduction).
Mean/var finalization happens outside the kernel (cheap, O(C)).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, sum_ref, sq_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    xb = x_ref[...]
    sum_ref[...] += xb.sum(axis=0, keepdims=True)
    sq_ref[...] += (xb * xb).sum(axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def channel_stats(x, *, block_rows=256):
    """x f32[..., C] -> (mu f32[C], var f32[C]) over all leading dims."""
    c = x.shape[-1]
    flat = x.reshape(-1, c)
    nrows = flat.shape[0]
    block_rows = min(block_rows, nrows)
    # pad rows to a multiple of the stripe; padded zeros are corrected below
    pad = (-nrows) % block_rows
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad, c), flat.dtype)], axis=0)
    grid = (flat.shape[0] // block_rows,)
    s, sq = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, c), lambda i: (0, 0)),
                   pl.BlockSpec((1, c), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, c), jnp.float32),
                   jax.ShapeDtypeStruct((1, c), jnp.float32)],
        interpret=True,
    )(flat)
    # padded rows contribute 0 to both accumulators; divide by true count
    mu = s[0] / nrows
    var = sq[0] / nrows - mu * mu
    return mu, var

"""Pallas fused LayerNorm / RMSNorm kernels — the ops Norm Tweaking perturbs.

Row-wise fused normalize+affine in a single VMEM pass (read x once, write y
once) — these are bandwidth-bound; fusing avoids materializing mean/var in
HBM.  The affine parameters (gamma, beta) are exactly the tensors Algorithm 1
updates.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-5


def _ln_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    o_ref[...] = (x - mu) * jax.lax.rsqrt(var + EPS) * g_ref[...] + b_ref[...]


def _rms_kernel(x_ref, g_ref, o_ref):
    x = x_ref[...]
    ms = (x * x).mean(axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + EPS) * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def layernorm(x, g, b, *, block_rows=128):
    """LayerNorm with affine over the last dim of f32[..., C]."""
    c = x.shape[-1]
    orig = x.shape
    flat = x.reshape(-1, c)
    nrows = flat.shape[0]
    block_rows = min(block_rows, nrows)
    pad = (-nrows) % block_rows
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad, c), flat.dtype)], axis=0)
    grid = (flat.shape[0] // block_rows,)
    y = pl.pallas_call(
        _ln_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
                  pl.BlockSpec((1, c), lambda i: (0, 0)),
                  pl.BlockSpec((1, c), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(flat, g.reshape(1, c), b.reshape(1, c))
    return y[:nrows].reshape(orig)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def rmsnorm(x, g, *, block_rows=128):
    """RMSNorm (gamma only) over the last dim of f32[..., C]."""
    c = x.shape[-1]
    orig = x.shape
    flat = x.reshape(-1, c)
    nrows = flat.shape[0]
    block_rows = min(block_rows, nrows)
    pad = (-nrows) % block_rows
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad, c), flat.dtype)], axis=0)
    grid = (flat.shape[0] // block_rows,)
    y = pl.pallas_call(
        _rms_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
                  pl.BlockSpec((1, c), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(flat, g.reshape(1, c))
    return y[:nrows].reshape(orig)

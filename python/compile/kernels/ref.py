"""Pure-jnp oracles for every Pallas kernel.

These are the correctness contracts: pytest (with hypothesis shape sweeps)
asserts each kernel in this package matches its oracle to tight tolerance.
The L2 model can be built against either implementation (`use_pallas` flag),
which is itself a tested equivalence.
"""

import jax.numpy as jnp


def quant_matmul(x, codes, scales):
    """Dequantize-then-matmul oracle.

    x:      f32[M, K]
    codes:  i8 [K, N]  symmetric integer codes
    scales: f32[G, N]  per-(group, out-channel) scales, G = K // group_size
    returns f32[M, N] = x @ (codes * scales_expanded)
    """
    k, n = codes.shape
    g = scales.shape[0]
    group = k // g
    w = codes.astype(jnp.float32).reshape(g, group, n) * scales[:, None, :]
    return x @ w.reshape(k, n)


def channel_stats(x):
    """Per-channel mean and (population) variance over all leading dims.

    x: f32[..., C] -> (mu f32[C], var f32[C])
    """
    flat = x.reshape(-1, x.shape[-1])
    mu = flat.mean(axis=0)
    var = ((flat - mu) ** 2).mean(axis=0)
    return mu, var


def layernorm(x, g, b, eps=1e-5):
    """Row-wise LayerNorm with affine: f32[..., C] -> f32[..., C]."""
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def rmsnorm(x, g, eps=1e-5):
    """Row-wise RMSNorm (no mean subtraction, no beta) — the LLaMa variant."""
    ms = (x * x).mean(axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * g


def rtn_quantize(w, bits, group_size):
    """Symmetric round-to-nearest per-(group, out-channel) quantization.

    w: f32[K, N]; group along K.  Returns (codes i8[K,N], scales f32[G,N]).
    qmax = 2^(bits-1) - 1 (symmetric, zero-point-free — the
    FasterTransformer-compatible scheme the paper uses).
    """
    k, n = w.shape
    assert k % group_size == 0
    g = k // group_size
    qmax = float(2 ** (bits - 1) - 1)
    wg = w.reshape(g, group_size, n)
    amax = jnp.max(jnp.abs(wg), axis=1)            # [G, N]
    scales = jnp.where(amax > 0, amax / qmax, 1.0)
    codes = jnp.clip(jnp.round(wg / scales[:, None, :]), -qmax, qmax)
    return codes.reshape(k, n).astype(jnp.int8), scales.astype(jnp.float32)


def dequantize(codes, scales):
    """Inverse of rtn_quantize's packing: f32[K, N] from codes + group scales."""
    k, n = codes.shape
    g = scales.shape[0]
    group = k // g
    w = codes.astype(jnp.float32).reshape(g, group, n) * scales[:, None, :]
    return w.reshape(k, n)


def attention(q, k, v, causal=True):
    """Multi-head scaled-dot-product attention oracle.

    q, k, v: f32[B, H, S, Dh] -> f32[B, H, S, Dh]
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask, scores, -1e30)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def dist_loss(mu_f, var_f, mu_q, var_q):
    """The paper's channel-wise distribution loss (Eq. 2).

    L = 1/C * sum_c ( ||mu_f^c - mu_q^c||_2 + ||var_f^c - var_q^c||_2 );
    the L2 norm of a scalar is its absolute value.
    """
    return (jnp.abs(mu_f - mu_q) + jnp.abs(var_f - var_q)).mean()

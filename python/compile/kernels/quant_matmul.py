"""Pallas dequantize-matmul kernel — the quantized-inference hot path.

TPU mapping of the paper's CUDA int4/int2 GEMM (see DESIGN.md
§Hardware-Adaptation): integer weight codes + per-(group, out-channel) scales
stream HBM→VMEM tile by tile; the weight tile is dequantized in VMEM by the
VPU and fed to the MXU as f32 (bf16 on real hardware).  BlockSpec expresses
the HBM↔VMEM schedule the CUDA version did with threadblocks + shared memory.

VMEM budget per grid step (f32 words):
    x tile   bm*bk      = 64*128 =  8K
    code tile bk*bn (i8) = 128*128 = 16KB as i8
    scale row 1*bn
    out tile bm*bn      = 64*128 =  8K
→ ~100 KB, leaving headroom for double buffering in a 16 MB VMEM.

Constraint: group_size % block_k == 0 so each K-tile falls in one scale group.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, s_ref, o_ref):
    # k is the innermost grid axis: zero the accumulator on the first step,
    # accumulate partial products after.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = c_ref[...].astype(jnp.float32) * s_ref[...]      # dequant in VMEM
    o_ref[...] += jnp.dot(x_ref[...], w,
                          preferred_element_type=jnp.float32)


def _tile(desired: int, dim: int) -> int:
    """Largest divisor of `dim` that is <= desired (tiles must cover dim)."""
    b = min(desired, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("group_size", "block_m",
                                             "block_n", "block_k"))
def quant_matmul(x, codes, scales, *, group_size=None,
                 block_m=256, block_n=256, block_k=64):
    # §Perf: default tiles were (64, 128, 64); under CPU-interpret the grid
    # lowers to an XLA while loop whose per-step overhead dominates, and on
    # real hardware larger tiles amortize the DMA setup. (256, 256, 64)
    # cuts grid steps ~8x while staying inside the VMEM budget documented
    # above (256*64 + 64*256 + 256*256 f32 ≈ 390 KB per step, double-
    # buffered < 1 MB of a 16 MB VMEM). Tiles snap down to divisors of the
    # actual dims.
    """x f32[M,K] @ dequant(codes i8[K,N], scales f32[G,N]) -> f32[M,N]."""
    m, k = x.shape
    kc, n = codes.shape
    g = scales.shape[0]
    assert kc == k, (kc, k)
    if group_size is None:
        group_size = k // g
    assert g * group_size == k, "scales incompatible with group_size"

    block_m = _tile(block_m, m)
    block_n = _tile(block_n, n)
    block_k = _tile(min(block_k, group_size), k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    assert group_size % block_k == 0, "K tile must not straddle a scale group"

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            # scale row of the group this K tile belongs to
            pl.BlockSpec((1, block_n),
                         lambda i, j, kk, gs=group_size // block_k:
                         (kk // gs, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,   # CPU PJRT cannot run Mosaic custom-calls
    )(x, codes, scales)

"""Blocked causal attention Pallas kernel (flash-attention style).

TPU re-think of the CUDA flash kernel: the (block_q, d_head) query tile and
the running (m, l, acc) softmax state live in VMEM; KV tiles stream in along
the innermost grid axis.  Because the grid's last axis iterates KV blocks,
pl.when-gated initialization + accumulator revisiting express the online
softmax without scratch semaphores — the structure a Mosaic lowering would
pipeline with double-buffered DMA.

Causality is handled at tile granularity: KV tiles strictly above the
diagonal are skipped via a mask of -inf contributions (tile-level `pl.when`
early-out is not available under revisiting, so we mask; XLA DCEs the
all-masked tiles under interpret=True anyway for our sizes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale,
            block_q, block_k, n_kv):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # [bq, dh]
    k = k_ref[0]                                   # [bk, dh]
    v = v_ref[0]                                   # [bk, dh]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    # causal mask at element granularity
    q_idx = pl.program_id(1)
    q_pos = q_idx * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (q.shape[0], k.shape[0]), 0)
    k_pos = kv_idx * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                        (q.shape[0], k.shape[0]), 1)
    s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]                            # [bq, 1]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(kv_idx == n_kv - 1)
    def _finalize():
        # guard rows that saw only masked tiles (l == 0 cannot happen for
        # causal q>=0, but keep the kernel total)
        l = l_ref[...]
        o_ref[0] = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def attention(q, k, v, *, block_q=64, block_k=64):
    """Causal MHA: q,k,v f32[B,H,S,Dh] -> f32[B,H,S,Dh]."""
    b, h, s, dh = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    scale = 1.0 / (dh ** 0.5)
    bh = b * h
    qf = q.reshape(bh, s, dh)
    kf = k.reshape(bh, s, dh)
    vf = v.reshape(bh, s, dh)
    n_kv = s // block_k
    grid = (bh, s // block_q, n_kv)
    kern = functools.partial(_kernel, scale=scale, block_q=block_q,
                             block_k=block_k, n_kv=n_kv)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, dh), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((block_q, 1), lambda g, i, j: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda g, i, j: (i, 0)),
            pl.BlockSpec((block_q, dh), lambda g, i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dh), jnp.float32),
            jax.ShapeDtypeStruct((s, 1), jnp.float32),        # running max
            jax.ShapeDtypeStruct((s, 1), jnp.float32),        # running sum
            jax.ShapeDtypeStruct((s, dh), jnp.float32),       # accumulator
        ],
        interpret=True,
    )(qf, kf, vf)[0]
    return out.reshape(b, h, s, dh)

"""Pallas RTN quantization kernel — symmetric per-(group, out-channel).

Each grid step owns one (group_size, block_n) weight tile: an abs-max VPU
reduction over the group axis produces the scale row, then the tile is
rounded and clipped in VMEM.  Grid steps are fully independent (no revisits),
so this kernel pipelines perfectly on real hardware.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(qmax):
    def _kernel(w_ref, c_ref, s_ref):
        w = w_ref[...]
        amax = jnp.abs(w).max(axis=0, keepdims=True)        # [1, bn]
        scale = jnp.where(amax > 0, amax / qmax, 1.0)
        codes = jnp.clip(jnp.round(w / scale), -qmax, qmax)
        c_ref[...] = codes.astype(jnp.int8)
        s_ref[...] = scale.astype(jnp.float32)
    return _kernel


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "block_n"))
def rtn_quantize(w, *, bits, group_size, block_n=128):
    """w f32[K, N] -> (codes i8[K, N], scales f32[K//group_size, N])."""
    k, n = w.shape
    assert k % group_size == 0, (k, group_size)
    g = k // group_size
    block_n = min(block_n, n)
    assert n % block_n == 0
    qmax = float(2 ** (bits - 1) - 1)
    grid = (g, n // block_n)
    codes, scales = pl.pallas_call(
        _make_kernel(qmax),
        grid=grid,
        in_specs=[pl.BlockSpec((group_size, block_n), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((group_size, block_n), lambda i, j: (i, j)),
                   pl.BlockSpec((1, block_n), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((k, n), jnp.int8),
                   jax.ShapeDtypeStruct((g, n), jnp.float32)],
        interpret=True,
    )(w)
    return codes, scales

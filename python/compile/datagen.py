"""Emit corpus goldens + eval datasets consumed by the Rust side.

Writes:
    artifacts/corpus_golden.ntz   first-N token prefixes of every named corpus
                                  (the Python↔Rust generator lock-step check)
    artifacts/lambada_syn.ntz     the LAMBADA-syn eval set (tokens + answer pos)
    artifacts/table1.json         corpus-share vs vocab-share stats (Table 1)
"""

import argparse
import json

import numpy as np

from . import ntz
from .configs import LANGS, VOCAB_SIZE
from .corpus import (C4_SYN, PTB_SYN, TRAIN_SPEC, WIKI_SYN, lambada_syn,
                     token_stream)

GOLDEN_N = 4096


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    tensors = {}
    for spec in (TRAIN_SPEC, WIKI_SYN, PTB_SYN, C4_SYN):
        toks = np.array(token_stream(spec, GOLDEN_N), dtype=np.int32)
        tensors[f"golden.{spec.name}"] = toks
    ntz.save(f"{args.out}/corpus_golden.ntz", tensors)

    items, pos = lambada_syn(seed=0x1A3B, n_items=256, seq=128)
    ntz.save(f"{args.out}/lambada_syn.ntz", {
        "tokens": np.array(items, dtype=np.int32),
        "answer_pos": np.array(pos, dtype=np.int32),
    })

    # Table 1 analog: corpus share (by construction) vs vocab share
    table1 = []
    for lang in LANGS[:5]:
        table1.append({
            "lang": lang.name,
            "corpus_share": lang.corpus_share,
            "vocab_tokens": lang.hi - lang.lo,
            "vocab_share": (lang.hi - lang.lo) / VOCAB_SIZE,
        })
    with open(f"{args.out}/table1.json", "w") as f:
        json.dump(table1, f, indent=1)
    print(f"[datagen] wrote corpus goldens, lambada-syn (256 items), table1")


if __name__ == "__main__":
    main()

"""Model size / architecture registry shared by model.py, aot.py and train.py.

The Rust side carries the same registry in `rust/src/model/config.rs`; the two
are cross-checked through `artifacts/manifest.json` (shapes) and the `.ntz`
checkpoints (tensor names).
"""

from dataclasses import dataclass, field


# --- vocabulary layout (mirrored exactly in rust/src/calib/vocab.rs) ---------
#
# The synthetic "multilingual" vocabulary reproduces the corpus-share vs
# vocab-share mismatch of Table 1 of the paper: the top-5 languages dominate
# the *corpus* (~78%) but own a small slice of the *vocabulary* (~24%), the
# long tail of languages owns the rest of the vocab.

VOCAB_SIZE = 2048

PAD, BOS, EOS, SEP, PERIOD, BIND, QUERY, UNK = 0, 1, 2, 3, 4, 5, 6, 7
N_SPECIAL = 8


@dataclass(frozen=True)
class Lang:
    name: str
    lo: int          # vocab bucket [lo, hi)
    hi: int
    corpus_share: float  # share of the synthetic training corpus
    salt: int        # grammar hash salt (u64)


# Top-5 "languages" + a 12-language tail sharing one big bucket.
LANGS = [
    Lang("en",  8,    168,  0.40, 0x9E3779B97F4A7C15),
    Lang("zhs", 168,  200,  0.18, 0xBF58476D1CE4E5B9),
    Lang("fr",  200,  328,  0.10, 0x94D049BB133111EB),
    Lang("es",  328,  424,  0.06, 0xD6E8FEB86659FD93),
    Lang("pt",  424,  488,  0.04, 0xA5A5A5A5A5A5A5A5),
    # tail languages (low corpus share, huge vocab share — the mismatch)
    Lang("t0",  488,  618,  0.03, 0x0123456789ABCDEF),
    Lang("t1",  618,  748,  0.03, 0xFEDCBA9876543210),
    Lang("t2",  748,  878,  0.02, 0x1111111111111111),
    Lang("t3",  878,  1008, 0.02, 0x2222222222222222),
    Lang("t4",  1008, 1138, 0.02, 0x3333333333333333),
    Lang("t5",  1138, 1268, 0.02, 0x4444444444444444),
    Lang("t6",  1268, 1398, 0.02, 0x5555555555555555),
    Lang("t7",  1398, 1528, 0.01, 0x6666666666666666),
    Lang("t8",  1528, 1658, 0.01, 0x7777777777777777),
    Lang("t9",  1658, 1788, 0.01, 0x8888888888888888),
    Lang("t10", 1788, 1918, 0.01, 0x9999999999999999),
    Lang("t11", 1918, 2048, 0.02, 0xAAAAAAAAAAAAAAAA),
]

TOP_LANGS = [l.name for l in LANGS[:5]]

assert abs(sum(l.corpus_share for l in LANGS) - 1.0) < 1e-9
assert LANGS[-1].hi == VOCAB_SIZE


# --- model architecture registry ---------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layer: int
    d_model: int
    n_head: int
    d_ff: int
    vocab: int = VOCAB_SIZE
    seq: int = 128           # max sequence length (pos-emb size); the
                             # scaled-down analog of the paper's 2048
    norm: str = "layernorm"  # "layernorm" | "rmsnorm"

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_head

    def param_names(self) -> list[str]:
        """Canonical checkpoint tensor names (must match rust model registry)."""
        names = ["tok_emb", "pos_emb"]
        for i in range(self.n_layer):
            p = f"block{i}."
            names += [p + "ln1.g", p + "attn.wqkv", p + "attn.bqkv",
                      p + "attn.wproj", p + "attn.bproj",
                      p + "ln2.g", p + "mlp.wfc1", p + "mlp.bfc1",
                      p + "mlp.wfc2", p + "mlp.bfc2"]
            if self.norm == "layernorm":
                names.insert(names.index(p + "attn.wqkv"), p + "ln1.b")
                names.insert(names.index(p + "mlp.wfc1"), p + "ln2.b")
        names += ["lnf.g"]
        if self.norm == "layernorm":
            names += ["lnf.b"]
        return names


MODELS = {
    "nt-tiny": ModelConfig("nt-tiny", n_layer=2, d_model=128, n_head=4, d_ff=512),
    "nt-small": ModelConfig("nt-small", n_layer=4, d_model=256, n_head=8, d_ff=1024),
    "nt-small-rms": ModelConfig("nt-small-rms", n_layer=4, d_model=256, n_head=8,
                                d_ff=1024, norm="rmsnorm"),
    "nt-medium": ModelConfig("nt-medium", n_layer=6, d_model=384, n_head=8, d_ff=1536),
}

# Batch buckets for which block-level graphs are exported.  The coordinator
# pads the calibration/eval batch to the nearest bucket.
BATCH_BUCKETS = [1, 8, 32]
# The tweak_step / xtx graphs only exist at the calibration bucket.
CALIB_BATCH = 32

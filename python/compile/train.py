"""Build-time pretraining of the synthetic-corpus models.

The paper quantizes *pretrained* LLMs; quantization damage (and Norm
Tweaking's repair) is only measurable on a model that has actual capability.
This script trains the registry models on the synthetic multilingual corpus
(next-token cross-entropy, Adam) and writes:

    artifacts/weights_<model>.ntz      float checkpoints (tensor registry)
    artifacts/train_log_<model>.json   loss curve + final holdout metrics
    artifacts/golden_<model>.ntz       (tokens, logits) parity pair for the
                                       Rust artifact-composition test

Run once via `make artifacts`.  Never on the request path.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import ntz
from .configs import MODELS, VOCAB_SIZE
from .corpus import TRAIN_SPEC, WIKI_SYN, lambada_syn, token_stream
from .model import init_params, model_fwd

B1, B2, EPS = 0.9, 0.999, 1e-8

# per-model training budget (steps tuned for CPU build time)
STEPS = {"nt-tiny": 500, "nt-small": 1000, "nt-small-rms": 1000, "nt-medium": 800}
BATCH = {"nt-tiny": 16, "nt-small": 16, "nt-small-rms": 16, "nt-medium": 12}
LR = 3e-4


def chunks(stream: np.ndarray, seq: int, batch: int, rng: np.random.Generator):
    """Sample random seq-length windows from the token stream."""
    n = len(stream) - seq - 1
    idx = rng.integers(0, n, size=batch)
    x = np.stack([stream[i:i + seq] for i in idx]).astype(np.int32)
    y = np.stack([stream[i + 1:i + seq + 1] for i in idx]).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


def make_step(cfg):
    def loss_fn(params, x, y):
        logits = model_fwd(cfg, x, params, use_pallas=False)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
        return -ll.mean()

    @jax.jit
    def step(params, m, v, t, x, y):
        loss, g = jax.value_and_grad(loss_fn)(params, x, y)
        new_p, new_m, new_v = {}, {}, {}
        bc1 = 1.0 - B1 ** t
        bc2 = 1.0 - B2 ** t
        for k in params:
            m2 = B1 * m[k] + (1 - B1) * g[k]
            v2 = B2 * v[k] + (1 - B2) * g[k] ** 2
            new_p[k] = params[k] - LR * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + EPS)
            new_m[k], new_v[k] = m2, v2
        return new_p, new_m, new_v, loss

    return step, jax.jit(loss_fn)


def lambada_acc(cfg, params, n_items=64, seed=0xACC):
    """Quick recall-task accuracy (the fp32 reference point for Table 2)."""
    items, pos = lambada_syn(seed, n_items, cfg.seq)
    toks = jnp.asarray(np.array(items, dtype=np.int32))
    logits = model_fwd(cfg, toks, params, use_pallas=False)
    correct = 0
    for i, p in enumerate(pos):
        pred = int(jnp.argmax(logits[i, p - 1]))
        if pred == items[i][p]:
            correct += 1
    return correct / n_items


def train_model(name: str, out_dir: str, steps: int | None = None):
    cfg = MODELS[name]
    steps = steps or STEPS[name]
    batch = BATCH[name]
    print(f"[train] {name}: {cfg.n_layer}L d={cfg.d_model} norm={cfg.norm} "
          f"steps={steps} batch={batch}")

    stream = np.array(token_stream(TRAIN_SPEC, 400_000), dtype=np.int32)
    holdout = np.array(token_stream(WIKI_SYN, 20_000), dtype=np.int32)
    rng = np.random.default_rng(0xDEC0DE)

    params = init_params(cfg, seed=1234)
    m = {k: jnp.zeros_like(x) for k, x in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    step, loss_fn = make_step(cfg)

    log = {"model": name, "steps": steps, "batch": batch, "lr": LR,
           "loss_curve": []}
    t0 = time.time()
    for it in range(1, steps + 1):
        x, y = chunks(stream, cfg.seq, batch, rng)
        params, m, v, loss = step(params, m, v, float(it), x, y)
        if it % 25 == 0 or it == 1:
            log["loss_curve"].append([it, float(loss)])
            print(f"  step {it:4d}  loss {float(loss):.4f}  "
                  f"({time.time() - t0:.0f}s)")

    hx, hy = chunks(holdout, cfg.seq, 8, np.random.default_rng(7))
    log["holdout_loss"] = float(loss_fn(params, hx, hy))
    log["lambada_syn_acc_fp32"] = lambada_acc(cfg, params)
    log["train_seconds"] = time.time() - t0
    print(f"  holdout loss {log['holdout_loss']:.4f}  "
          f"lambada-syn acc {log['lambada_syn_acc_fp32']:.3f}")

    np_params = {k: np.asarray(x) for k, x in params.items()}
    ntz.save(f"{out_dir}/weights_{name}.ntz", np_params)
    with open(f"{out_dir}/train_log_{name}.json", "w") as f:
        json.dump(log, f, indent=1)

    # parity golden: 2 random sequences + their logits
    gt = jnp.asarray(rng.integers(0, VOCAB_SIZE, size=(2, cfg.seq)),
                     dtype=jnp.int32)
    gl = model_fwd(cfg, gt, params, use_pallas=False)
    ntz.save(f"{out_dir}/golden_{name}.ntz",
             {"tokens": np.asarray(gt).astype(np.int32),
              "logits": np.asarray(gl)})
    return log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=list(MODELS))
    ap.add_argument("--steps", type=int, default=None,
                    help="override step count (smoke runs)")
    ap.add_argument("--force", action="store_true",
                    help="retrain even if a checkpoint exists")
    args = ap.parse_args()
    for name in args.models:
        out = f"{args.out}/weights_{name}.ntz"
        if os.path.exists(out) and not args.force:
            print(f"[train] {name}: {out} exists, skipping (use --force)")
            continue
        train_model(name, args.out, args.steps)


if __name__ == "__main__":
    main()

"""L2 — the JAX transformer and the Norm-Tweaking compute graphs.

Everything here is *build-time only*: `aot.py` lowers these functions once to
HLO text; the Rust coordinator composes them layer by layer at runtime
(embed → block_fwd[_q] × L → head), which is exactly the structure Algorithm 1
needs (the float and quantized streams advance one transformer layer at a
time, with weights as graph *arguments* so quantization can swap them).

Weight calling convention (must match rust/src/model/registry.rs and the
manifest): per block, in order —

  layernorm: ln1.g ln1.b  attn.wqkv attn.bqkv attn.wproj attn.bproj
             ln2.g ln2.b  mlp.wfc1 mlp.bfc1 mlp.wfc2 mlp.bfc2
  rmsnorm:   same without ln1.b / ln2.b

Quantized blocks replace each weight matrix `w*` with (codes i8, scales f32).

Differentiability note: the `tweak_step` graph (loss + grad + Adam fused) is
built on the pure-jnp oracles because `pallas_call` has no VJP; the Pallas
kernels serve the inference graphs.  Kernel≡oracle is pytest-enforced, so the
two paths are numerically interchangeable.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.attention import attention as pallas_attention
from .kernels.norms import layernorm as pallas_layernorm
from .kernels.norms import rmsnorm as pallas_rmsnorm
from .kernels.quant_matmul import quant_matmul as pallas_quant_matmul

# ---------------------------------------------------------------------------
# weight plumbing


def n_block_weights(cfg: ModelConfig) -> int:
    return 12 if cfg.norm == "layernorm" else 10


def n_block_qweights(cfg: ModelConfig) -> int:
    # each of the 4 weight matrices becomes (codes, scales)
    return n_block_weights(cfg) + 4


@dataclass
class BlockWeights:
    """Float weights of one transformer block, in canonical order."""
    ln1_g: jax.Array
    ln1_b: jax.Array | None
    wqkv: jax.Array
    bqkv: jax.Array
    wproj: jax.Array
    bproj: jax.Array
    ln2_g: jax.Array
    ln2_b: jax.Array | None
    wfc1: jax.Array
    bfc1: jax.Array
    wfc2: jax.Array
    bfc2: jax.Array

    @staticmethod
    def from_flat(cfg: ModelConfig, flat):
        if cfg.norm == "layernorm":
            (ln1_g, ln1_b, wqkv, bqkv, wproj, bproj,
             ln2_g, ln2_b, wfc1, bfc1, wfc2, bfc2) = flat
        else:
            (ln1_g, wqkv, bqkv, wproj, bproj,
             ln2_g, wfc1, bfc1, wfc2, bfc2) = flat
            ln1_b = ln2_b = None
        return BlockWeights(ln1_g, ln1_b, wqkv, bqkv, wproj, bproj,
                            ln2_g, ln2_b, wfc1, bfc1, wfc2, bfc2)


@dataclass
class BlockQWeights:
    """Quantized weights of one block: (codes, scales) per matrix + norms."""
    ln1_g: jax.Array
    ln1_b: jax.Array | None
    cqkv: jax.Array
    sqkv: jax.Array
    bqkv: jax.Array
    cproj: jax.Array
    sproj: jax.Array
    bproj: jax.Array
    ln2_g: jax.Array
    ln2_b: jax.Array | None
    cfc1: jax.Array
    sfc1: jax.Array
    bfc1: jax.Array
    cfc2: jax.Array
    sfc2: jax.Array
    bfc2: jax.Array

    @staticmethod
    def from_flat(cfg: ModelConfig, flat):
        if cfg.norm == "layernorm":
            (ln1_g, ln1_b, cqkv, sqkv, bqkv, cproj, sproj, bproj,
             ln2_g, ln2_b, cfc1, sfc1, bfc1, cfc2, sfc2, bfc2) = flat
        else:
            (ln1_g, cqkv, sqkv, bqkv, cproj, sproj, bproj,
             ln2_g, cfc1, sfc1, bfc1, cfc2, sfc2, bfc2) = flat
            ln1_b = ln2_b = None
        return BlockQWeights(ln1_g, ln1_b, cqkv, sqkv, bqkv, cproj, sproj,
                             bproj, ln2_g, ln2_b, cfc1, sfc1, bfc1,
                             cfc2, sfc2, bfc2)


# ---------------------------------------------------------------------------
# primitive wrappers (pallas vs oracle)


def _norm(cfg, x, g, b, use_pallas):
    if cfg.norm == "layernorm":
        if use_pallas:
            return pallas_layernorm(x, g, b)
        return ref.layernorm(x, g, b)
    if use_pallas:
        return pallas_rmsnorm(x, g)
    return ref.rmsnorm(x, g)


def _attn(q, k, v, use_pallas):
    if use_pallas:
        return pallas_attention(q, k, v)
    return ref.attention(q, k, v)


def _qmm(x2d, codes, scales, use_pallas):
    if use_pallas:
        return pallas_quant_matmul(x2d, codes, scales)
    return ref.quant_matmul(x2d, codes, scales)


def _gelu(x):
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# forward passes


def _attention_mix(cfg: ModelConfig, x, qkv):
    """Split fused qkv [B,S,3d] into heads, attend, merge back to [B,S,d]."""
    b, s, _ = x.shape
    h, dh = cfg.n_head, cfg.d_head
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3)

    return None, heads(q), heads(k), heads(v)


def block_fwd_kv(cfg: ModelConfig, x, flat_weights, use_pallas=True):
    """Float block forward that also returns the per-head K/V tensors.

    The prefill graph of the incremental-decode runtime: the Rust side
    composes `embed → block_fwd_kv × L → head` once per prompt and seeds a
    per-layer KV cache from the returned K/V (positions past the prompt
    hold pad-token junk, but decode masks to `<= pos` and overwrites them
    one step at a time, so they are never attended before being rewritten).

    Returns (x_out [B,S,d], k [B,H,S,dh], v [B,H,S,dh]).
    """
    w = BlockWeights.from_flat(cfg, flat_weights)
    b, s, d = x.shape

    h1 = _norm(cfg, x, w.ln1_g, w.ln1_b, use_pallas)
    qkv = (h1.reshape(b * s, d) @ w.wqkv + w.bqkv).reshape(b, s, 3 * d)
    _, q, k, v = _attention_mix(cfg, x, qkv)
    a = _attn(q, k, v, use_pallas)
    a = a.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + (a.reshape(b * s, d) @ w.wproj + w.bproj).reshape(b, s, d)

    h2 = _norm(cfg, x, w.ln2_g, w.ln2_b, use_pallas)
    f = _gelu(h2.reshape(b * s, d) @ w.wfc1 + w.bfc1)
    x = x + (f @ w.wfc2 + w.bfc2).reshape(b, s, d)
    return x, k, v


def block_fwd(cfg: ModelConfig, x, flat_weights, use_pallas=True):
    """Float transformer block: pre-norm attention + pre-norm MLP."""
    return block_fwd_kv(cfg, x, flat_weights, use_pallas)[0]


def block_taps(cfg: ModelConfig, x, flat_weights, use_pallas=True):
    """The four linear-layer *input* activations (GPTQ Hessian taps).

    Returns (t_qkv [B,S,d], t_proj [B,S,d], t_fc1 [B,S,d], t_fc2 [B,S,ff]):
    the tensors whose Gram matrices are the OBS Hessians for wqkv, wproj,
    wfc1, wfc2 respectively.
    """
    w = BlockWeights.from_flat(cfg, flat_weights)
    b, s, d = x.shape

    t_qkv = _norm(cfg, x, w.ln1_g, w.ln1_b, use_pallas)
    qkv = (t_qkv.reshape(b * s, d) @ w.wqkv + w.bqkv).reshape(b, s, 3 * d)
    _, q, k, v = _attention_mix(cfg, x, qkv)
    a = _attn(q, k, v, use_pallas)
    t_proj = a.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + (t_proj.reshape(b * s, d) @ w.wproj + w.bproj).reshape(b, s, d)

    t_fc1 = _norm(cfg, x, w.ln2_g, w.ln2_b, use_pallas)
    t_fc2 = _gelu(t_fc1.reshape(b * s, d) @ w.wfc1 + w.bfc1).reshape(b, s, cfg.d_ff)
    return t_qkv, t_proj, t_fc1, t_fc2


def block_fwd_q_kv(cfg: ModelConfig, x, flat_qweights, use_pallas=True):
    """Quantized block forward that also returns the per-head K/V tensors
    (the quantized prefill graph — see [`block_fwd_kv`])."""
    w = BlockQWeights.from_flat(cfg, flat_qweights)
    b, s, d = x.shape

    h1 = _norm(cfg, x, w.ln1_g, w.ln1_b, use_pallas)
    qkv = (_qmm(h1.reshape(b * s, d), w.cqkv, w.sqkv, use_pallas)
           + w.bqkv).reshape(b, s, 3 * d)
    _, q, k, v = _attention_mix(cfg, x, qkv)
    a = _attn(q, k, v, use_pallas)
    a = a.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + (_qmm(a.reshape(b * s, d), w.cproj, w.sproj, use_pallas)
             + w.bproj).reshape(b, s, d)

    h2 = _norm(cfg, x, w.ln2_g, w.ln2_b, use_pallas)
    f = _gelu(_qmm(h2.reshape(b * s, d), w.cfc1, w.sfc1, use_pallas) + w.bfc1)
    x = x + (_qmm(f, w.cfc2, w.sfc2, use_pallas) + w.bfc2).reshape(b, s, d)
    return x, k, v


def block_fwd_q(cfg: ModelConfig, x, flat_qweights, use_pallas=True):
    """Quantized transformer block: dequant-matmul for all four linears."""
    return block_fwd_q_kv(cfg, x, flat_qweights, use_pallas)[0]


def embed(cfg: ModelConfig, tokens, tok_emb, pos_emb):
    """tokens i32[B,S] -> x0 f32[B,S,d]."""
    s = tokens.shape[1]
    return tok_emb[tokens] + pos_emb[:s][None, :, :]


def head(cfg: ModelConfig, x, lnf_flat, tok_emb, use_pallas=True):
    """Final norm + tied-embedding logits: x[B,S,d] -> logits f32[B,S,V]."""
    if cfg.norm == "layernorm":
        g, bb = lnf_flat
    else:
        (g,) = lnf_flat
        bb = None
    h = _norm(cfg, x, g, bb, use_pallas)
    return h @ tok_emb.T


# ---------------------------------------------------------------------------
# incremental decode (fixed-shape one-token step over a per-layer KV cache)
#
# All decode-side graphs use the jnp oracle kernels: a one-token step is a
# handful of GEMVs plus a masked attention row — there is nothing for the
# Pallas tiles to win, and the cache scatter/mask logic stays readable.
# Per-row positions (`pos` i32[B]) make the graphs continuous-batching
# ready: rows of one decode batch may sit at different sequence depths.


def _decode_attend(cfg: ModelConfig, q, k_cache, v_cache, pos):
    """One-token causal attention over the cache.

    q f32[B,H,1,dh] attends to cache rows `<= pos[b]` (the freshly written
    position included); everything deeper is masked out, so stale prefill
    junk past the live prefix is never read.
    """
    s = k_cache.shape[2]
    scale = 1.0 / (cfg.d_head ** 0.5)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k_cache) * scale   # [B,H,1,S]
    kidx = jnp.arange(s, dtype=jnp.int32)
    mask = kidx[None, None, None, :] <= pos[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v_cache)


def _cache_update(cache, new, pos):
    """Write `new` f32[B,H,1,dh] into `cache` f32[B,H,S,dh] at row `pos[b]`
    (vectorized one-hot scatter — fixed-shape, so it lowers AOT)."""
    s = cache.shape[2]
    oh = jax.nn.one_hot(pos, s, dtype=cache.dtype)               # [B,S]
    oh = oh[:, None, :, None]                                    # [B,1,S,1]
    return cache * (1.0 - oh) + new * oh


def embed_dec(cfg: ModelConfig, tokens, pos, tok_emb, pos_emb):
    """One-token embed: tokens i32[B,1] at per-row positions -> x f32[B,1,d]."""
    return tok_emb[tokens[:, 0]][:, None, :] + pos_emb[pos][:, None, :]


def _block_dec_attn(cfg: ModelConfig, x, pos, qkv, k_cache, v_cache):
    """Shared decode attention tail: split heads, scatter K/V, attend."""
    b = x.shape[0]
    _, q, k, v = _attention_mix(cfg, x, qkv)                     # [B,H,1,dh]
    k_cache = _cache_update(k_cache, k, pos)
    v_cache = _cache_update(v_cache, v, pos)
    a = _decode_attend(cfg, q, k_cache, v_cache, pos)
    a = a.transpose(0, 2, 1, 3).reshape(b, 1, cfg.d_model)
    return a, k_cache, v_cache


def block_dec(cfg: ModelConfig, x, pos, flat_weights, k_cache, v_cache):
    """Float one-token block step.

    x f32[B,1,d] is the new token's activation, `pos` i32[B] its absolute
    position per row, caches f32[B,H,S,dh].  Returns (x', k', v') — the
    caches come last in both directions so the runtime can thread them as
    carried state (`Runtime::run_carry`).
    """
    w = BlockWeights.from_flat(cfg, flat_weights)
    b, _, d = x.shape
    h1 = _norm(cfg, x, w.ln1_g, w.ln1_b, use_pallas=False)
    qkv = (h1.reshape(b, d) @ w.wqkv + w.bqkv).reshape(b, 1, 3 * d)
    a, k_cache, v_cache = _block_dec_attn(cfg, x, pos, qkv, k_cache, v_cache)
    x = x + (a.reshape(b, d) @ w.wproj + w.bproj).reshape(b, 1, d)
    h2 = _norm(cfg, x, w.ln2_g, w.ln2_b, use_pallas=False)
    f = _gelu(h2.reshape(b, d) @ w.wfc1 + w.bfc1)
    x = x + (f @ w.wfc2 + w.bfc2).reshape(b, 1, d)
    return x, k_cache, v_cache


def block_dec_q(cfg: ModelConfig, x, pos, flat_qweights, k_cache, v_cache):
    """Quantized one-token block step (see [`block_dec`])."""
    w = BlockQWeights.from_flat(cfg, flat_qweights)
    b, _, d = x.shape
    h1 = _norm(cfg, x, w.ln1_g, w.ln1_b, use_pallas=False)
    qkv = (_qmm(h1.reshape(b, d), w.cqkv, w.sqkv, False)
           + w.bqkv).reshape(b, 1, 3 * d)
    a, k_cache, v_cache = _block_dec_attn(cfg, x, pos, qkv, k_cache, v_cache)
    x = x + (_qmm(a.reshape(b, d), w.cproj, w.sproj, False)
             + w.bproj).reshape(b, 1, d)
    h2 = _norm(cfg, x, w.ln2_g, w.ln2_b, use_pallas=False)
    f = _gelu(_qmm(h2.reshape(b, d), w.cfc1, w.sfc1, False) + w.bfc1)
    x = x + (_qmm(f, w.cfc2, w.sfc2, False) + w.bfc2).reshape(b, 1, d)
    return x, k_cache, v_cache


def model_fwd(cfg: ModelConfig, tokens, params: dict, use_pallas=False):
    """Full float forward from a name->array dict (training / golden logits)."""
    x = embed(cfg, tokens, params["tok_emb"], params["pos_emb"])
    for i in range(cfg.n_layer):
        p = f"block{i}."
        if cfg.norm == "layernorm":
            flat = [params[p + n] for n in
                    ("ln1.g", "ln1.b", "attn.wqkv", "attn.bqkv", "attn.wproj",
                     "attn.bproj", "ln2.g", "ln2.b", "mlp.wfc1", "mlp.bfc1",
                     "mlp.wfc2", "mlp.bfc2")]
        else:
            flat = [params[p + n] for n in
                    ("ln1.g", "attn.wqkv", "attn.bqkv", "attn.wproj",
                     "attn.bproj", "ln2.g", "mlp.wfc1", "mlp.bfc1",
                     "mlp.wfc2", "mlp.bfc2")]
        x = block_fwd(cfg, x, flat, use_pallas=use_pallas)
    lnf = ([params["lnf.g"], params["lnf.b"]] if cfg.norm == "layernorm"
           else [params["lnf.g"]])
    return head(cfg, x, lnf, params["tok_emb"], use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# the Norm-Tweaking step (Algorithm 1 lines 11-15, fused into one XLA call)

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def _norm_param_names(cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return ("ln1_g", "ln1_b", "ln2_g", "ln2_b")
    return ("ln1_g", "ln2_g")


def tweak_step(cfg: ModelConfig, x, flat_qweights, adam_m, adam_v,
               mu_f, var_f, lr, t):
    """One fused tweak iteration.

    Inputs:
      x             f32[B,S,d]   layer input (the *quantized* stream qOut_{l-1})
      flat_qweights               quantized block weights (norm params inside
                                  are the *current* tweakable values)
      adam_m/adam_v list[f32[d]] Adam state per norm param
      mu_f, var_f   f32[d]       target channel stats of the float output
      lr            f32[1]       learning rate (layer-scheduled by L3)
      t             f32[1]       1-based Adam timestep

    Returns: (new norm params..., new m..., new v..., loss f32[1])

    The whole thing — quant fwd, channel stats, L_dist, backward, Adam — is
    one XLA executable, so L3's inner loop is a single PJRT call per iter.
    """
    w = BlockQWeights.from_flat(cfg, flat_qweights)
    names = _norm_param_names(cfg)
    theta = [getattr(w, n) for n in names]

    def loss_fn(theta_list):
        for n, v_ in zip(names, theta_list):
            setattr(w, n, v_)
        flat = _qweights_to_flat(cfg, w)
        y = block_fwd_q(cfg, x, flat, use_pallas=False)  # oracle path: differentiable
        mu_q, var_q = ref.channel_stats(y)
        return ref.dist_loss(mu_f, var_f, mu_q, var_q)

    loss, grads = jax.value_and_grad(loss_fn)(theta)

    lr0 = lr.reshape(())
    tt = t.reshape(())
    bc1 = 1.0 - ADAM_B1 ** tt
    bc2 = 1.0 - ADAM_B2 ** tt
    new_theta, new_m, new_v = [], [], []
    for th, g, m, v in zip(theta, grads, adam_m, adam_v):
        m2 = ADAM_B1 * m + (1 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1 - ADAM_B2) * (g * g)
        mhat = m2 / bc1
        vhat = v2 / bc2
        new_theta.append(th - lr0 * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(m2)
        new_v.append(v2)
    return tuple(new_theta) + tuple(new_m) + tuple(new_v) + (loss.reshape(1),)


def _qweights_to_flat(cfg: ModelConfig, w: BlockQWeights):
    if cfg.norm == "layernorm":
        return [w.ln1_g, w.ln1_b, w.cqkv, w.sqkv, w.bqkv, w.cproj, w.sproj,
                w.bproj, w.ln2_g, w.ln2_b, w.cfc1, w.sfc1, w.bfc1,
                w.cfc2, w.sfc2, w.bfc2]
    return [w.ln1_g, w.cqkv, w.sqkv, w.bqkv, w.cproj, w.sproj, w.bproj,
            w.ln2_g, w.cfc1, w.sfc1, w.bfc1, w.cfc2, w.sfc2, w.bfc2]


def channel_stats_graph(x):
    """Standalone (mu, var) graph used to compute float-stream targets."""
    mu, var = ref.channel_stats(x)
    return mu, var


def xtx(x2d):
    """Gram matrix X^T X for GPTQ Hessian accumulation. x2d f32[N,K]."""
    return x2d.T @ x2d


# convenience: alternative tweak losses for the Table-9 ablation -------------

def tweak_step_mse(cfg, x, flat_qweights, adam_m, adam_v, y_f, lr, t):
    """Ablation variant: point-wise MSE to the float output tensor."""
    w = BlockQWeights.from_flat(cfg, flat_qweights)
    names = _norm_param_names(cfg)
    theta = [getattr(w, n) for n in names]

    def loss_fn(theta_list):
        for n, v_ in zip(names, theta_list):
            setattr(w, n, v_)
        y = block_fwd_q(cfg, x, _qweights_to_flat(cfg, w), use_pallas=False)
        return ((y - y_f) ** 2).mean()

    loss, grads = jax.value_and_grad(loss_fn)(theta)
    return _adam_apply(theta, grads, adam_m, adam_v, lr, t, loss)


def tweak_step_kl(cfg, x, flat_qweights, adam_m, adam_v, y_f, lr, t):
    """Ablation variant: KL divergence over channel softmax distributions."""
    w = BlockQWeights.from_flat(cfg, flat_qweights)
    names = _norm_param_names(cfg)
    theta = [getattr(w, n) for n in names]

    def loss_fn(theta_list):
        for n, v_ in zip(names, theta_list):
            setattr(w, n, v_)
        y = block_fwd_q(cfg, x, _qweights_to_flat(cfg, w), use_pallas=False)
        pf = jax.nn.log_softmax(y_f, axis=-1)
        pq = jax.nn.log_softmax(y, axis=-1)
        return (jnp.exp(pf) * (pf - pq)).sum(-1).mean()

    loss, grads = jax.value_and_grad(loss_fn)(theta)
    return _adam_apply(theta, grads, adam_m, adam_v, lr, t, loss)


def _adam_apply(theta, grads, adam_m, adam_v, lr, t, loss):
    lr0 = lr.reshape(())
    tt = t.reshape(())
    bc1 = 1.0 - ADAM_B1 ** tt
    bc2 = 1.0 - ADAM_B2 ** tt
    new_theta, new_m, new_v = [], [], []
    for th, g, m, v in zip(theta, grads, adam_m, adam_v):
        m2 = ADAM_B1 * m + (1 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1 - ADAM_B2) * (g * g)
        new_theta.append(th - lr0 * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + ADAM_EPS))
        new_m.append(m2)
        new_v.append(v2)
    return tuple(new_theta) + tuple(new_m) + tuple(new_v) + (loss.reshape(1),)


# ---------------------------------------------------------------------------
# initialization (used by train.py)


def init_params(cfg: ModelConfig, seed: int) -> dict:
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4 + 8 * cfg.n_layer)
    d, ff, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq
    std = 0.02
    p = {
        "tok_emb": jax.random.normal(ks[0], (v, d)) * std,
        "pos_emb": jax.random.normal(ks[1], (s, d)) * std,
        "lnf.g": jnp.ones((d,)),
    }
    if cfg.norm == "layernorm":
        p["lnf.b"] = jnp.zeros((d,))
    ki = 2
    for i in range(cfg.n_layer):
        pre = f"block{i}."
        p[pre + "ln1.g"] = jnp.ones((d,))
        p[pre + "ln2.g"] = jnp.ones((d,))
        if cfg.norm == "layernorm":
            p[pre + "ln1.b"] = jnp.zeros((d,))
            p[pre + "ln2.b"] = jnp.zeros((d,))
        p[pre + "attn.wqkv"] = jax.random.normal(ks[ki], (d, 3 * d)) * std
        p[pre + "attn.bqkv"] = jnp.zeros((3 * d,))
        p[pre + "attn.wproj"] = (jax.random.normal(ks[ki + 1], (d, d))
                                 * std / (2 * cfg.n_layer) ** 0.5)
        p[pre + "attn.bproj"] = jnp.zeros((d,))
        p[pre + "mlp.wfc1"] = jax.random.normal(ks[ki + 2], (d, ff)) * std
        p[pre + "mlp.bfc1"] = jnp.zeros((ff,))
        p[pre + "mlp.wfc2"] = (jax.random.normal(ks[ki + 3], (ff, d))
                               * std / (2 * cfg.n_layer) ** 0.5)
        p[pre + "mlp.bfc2"] = jnp.zeros((d,))
        ki += 4
    return {k: v.astype(jnp.float32) for k, v in p.items()}

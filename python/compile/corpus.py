"""Deterministic synthetic multilingual corpus.

This module is mirrored *bit-for-bit* by ``rust/src/calib/corpus.rs``; the
cross-check test (`rust/tests/corpus_crosscheck.rs` vs golden tokens written
by ``make artifacts``) keeps the two in lock-step.

Design (see DESIGN.md §2):

* 17 "languages" over disjoint vocab buckets; the top-5 dominate the corpus
  (~78%) but own only ~24% of the vocabulary — reproducing the Table-1
  corpus-vs-vocab mismatch that motivates GenData-V2.
* Each language has a deterministic *successor grammar*: with probability
  ~0.85 the next word is ``succ(w) = lo + mix(w * K + salt) % B``; otherwise
  random in-bucket.  A small transformer learns this structure quickly, so
  quantization damage is measurable.
* **Recall sequences** are the LAMBADA-syn analog: key/value bindings early in
  the sequence must be recalled at the end (`QUERY k -> v`).  Last-token
  accuracy on held-out recall sequences is our Table-2 metric.
* Three held-out corpora ("wiki-syn", "ptb-syn", "c4-syn") use different
  language mixes / document statistics — the cross-dataset generalization axis
  of Table 8.

All randomness is a splitmix64 stream — identical u64 semantics in Python
(masked) and Rust (wrapping).
"""

from dataclasses import dataclass

from .configs import (BIND, BOS, EOS, LANGS, PERIOD, QUERY, VOCAB_SIZE, Lang)

MASK = (1 << 64) - 1
MIX_K = 0x2545F4914F6CDD1D


class SplitMix64:
    """splitmix64 PRNG — mirrored by rust/src/calib/rng.rs."""

    def __init__(self, seed: int):
        self.state = seed & MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK

    def below(self, n: int) -> int:
        """Uniform in [0, n) via simple modulo (bias negligible for n << 2^64)."""
        return self.next_u64() % n

    def chance(self, num: int, den: int) -> bool:
        """True with probability num/den."""
        return self.below(den) < num


def mix64(x: int) -> int:
    """Stateless avalanche hash (same finalizer as splitmix64)."""
    z = x & MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return (z ^ (z >> 31)) & MASK


def successor(word: int, lang: Lang) -> int:
    """Deterministic grammar successor of `word` inside `lang`'s bucket."""
    b = lang.hi - lang.lo
    return lang.lo + mix64((word * MIX_K + lang.salt) & MASK) % b


def sentence(rng: SplitMix64, lang: Lang) -> list[int]:
    """One grammar sentence: 4..11 words, 85% successor / 15% random, PERIOD."""
    b = lang.hi - lang.lo
    n = 4 + rng.below(8)
    w = lang.lo + rng.below(b)
    out = [w]
    for _ in range(n - 1):
        if rng.chance(85, 100):
            w = successor(w, lang)
        else:
            w = lang.lo + rng.below(b)
        out.append(w)
    out.append(PERIOD)
    return out


def recall_sequence(rng: SplitMix64, lang: Lang, n_bind: int = 2,
                    filler_sents: int = 1) -> list[int]:
    """LAMBADA-syn item: bindings, filler, then QUERY key -> value.

    Layout: BOS k1 v1 BIND k2 v2 BIND <filler> QUERY k_r v_r EOS
    The final `v_r` is deterministically recoverable only from the binding
    stated 10-20 tokens earlier — the long-range dependency that makes this
    the LAMBADA analog (an induction capability that low-bit quantization
    measurably destroys).
    """
    b = lang.hi - lang.lo
    keys: list[int] = []
    vals: list[int] = []
    # distinct keys so the query is unambiguous
    while len(keys) < n_bind:
        k = lang.lo + rng.below(b)
        if k not in keys:
            keys.append(k)
            vals.append(lang.lo + rng.below(b))
    out = [BOS]
    for k, v in zip(keys, vals):
        out += [k, v, BIND]
    for _ in range(filler_sents):
        out += sentence(rng, lang)
    r = rng.below(n_bind)
    out += [QUERY, keys[r], vals[r], EOS]
    return out


@dataclass(frozen=True)
class MixSpec:
    """A corpus = a language mix + document shape + recall share."""
    name: str
    seed: int
    # per-language weight overrides; None -> use Lang.corpus_share
    weights: tuple[float, ...] | None = None
    recall_permille: int = 150   # share of recall sequences, out of 1000
    doc_min: int = 64
    doc_max: int = 256


def _mix_weights(spec: MixSpec) -> list[float]:
    if spec.weights is None:
        return [l.corpus_share for l in LANGS]
    assert len(spec.weights) == len(LANGS)
    return list(spec.weights)


def pick_lang(rng: SplitMix64, weights: list[float]) -> Lang:
    """Weighted language choice using integer per-mille thresholds.

    Integer arithmetic keeps Python/Rust behaviour identical.
    """
    permille = [int(w * 1000) for w in weights]
    total = sum(permille)
    r = rng.below(total)
    acc = 0
    for lang, p in zip(LANGS, permille):
        acc += p
        if r < acc:
            return lang
    return LANGS[-1]


def document(rng: SplitMix64, lang: Lang, spec: MixSpec) -> list[int]:
    """One document: BOS, sentences (or a recall block), EOS."""
    if rng.below(1000) < spec.recall_permille:
        return recall_sequence(rng, lang)
    target = spec.doc_min + rng.below(spec.doc_max - spec.doc_min)
    out = [BOS]
    while len(out) < target:
        out += sentence(rng, lang)
    out.append(EOS)
    return out


def token_stream(spec: MixSpec, n_tokens: int) -> list[int]:
    """Concatenate documents until at least n_tokens; truncate exactly."""
    rng = SplitMix64(spec.seed)
    weights = _mix_weights(spec)
    out: list[int] = []
    while len(out) < n_tokens:
        lang = pick_lang(rng, weights)
        out += document(rng, lang, spec)
    return out[:n_tokens]


# --- the named corpora --------------------------------------------------------

def _w(d: dict[str, float]) -> tuple[float, ...]:
    """Build a full weight vector from a sparse {lang: weight} dict."""
    rest = [l for l in LANGS if l.name not in d]
    leftover = max(0.0, 1.0 - sum(d.values()))
    per = leftover / len(rest) if rest else 0.0
    return tuple(d.get(l.name, per) for l in LANGS)


TRAIN_SPEC = MixSpec("train", seed=0xC0FFEE)

# Held-out corpora with distinct distributions (Table 8's dataset axis).
WIKI_SYN = MixSpec("wiki-syn", seed=0x71C1, weights=_w({"en": 0.70, "fr": 0.15}),
                   recall_permille=150, doc_min=96, doc_max=256)
PTB_SYN = MixSpec("ptb-syn", seed=0x97B2, weights=_w({"en": 0.45, "zhs": 0.30, "es": 0.15}),
                  recall_permille=100, doc_min=48, doc_max=128)
C4_SYN = MixSpec("c4-syn", seed=0xC4C4,
                 weights=_w({"en": 0.25, "zhs": 0.15, "fr": 0.15, "es": 0.12, "pt": 0.10}),
                 recall_permille=250, doc_min=64, doc_max=224)

EVAL_SPECS = {"wiki-syn": WIKI_SYN, "ptb-syn": PTB_SYN, "c4-syn": C4_SYN}


def lambada_syn(seed: int, n_items: int, seq: int) -> tuple[list[list[int]], list[int]]:
    """The LAMBADA-syn eval set: successor-cloze items + answer positions.

    Each item is `BOS + <grammar sentence prefix>` whose final transition is
    forced to the deterministic grammar successor; the answer token is
    recoverable only from the association tables the model stores in its
    weights (the analog of LAMBADA's knowledge-demanding last word; see
    DESIGN.md §2 — a true long-range binding-recall variant exists in the
    corpus as `recall_sequence` but is not learnable within the build-time
    training budget, so the capability axis retained is *weight-stored
    knowledge recall*, which low-bit quantization measurably destroys).

    Returns (items, answer_pos) where items[i][answer_pos[i]] is the target
    and everything before it is context.  Drawn from top-5 languages only
    (the capability the models actually master).
    """
    rng = SplitMix64(seed)
    items: list[list[int]] = []
    pos: list[int] = []
    while len(items) < n_items:
        lang = LANGS[rng.below(5)]
        sent = sentence(rng, lang)[:-1]  # drop PERIOD
        seqt = [BOS] + sent
        if len(seqt) > seq:
            continue
        # force the final transition to be deterministic
        seqt[-1] = successor(seqt[-2], lang)
        p = len(seqt) - 1
        padded = seqt + [0] * (seq - len(seqt))
        items.append(padded)
        pos.append(p)
    return items, pos

"""`.ntz` — the tiny tensor-archive format shared between Python and Rust.

Layout (little-endian):

    magic   b"NTZ1"
    u32     n_tensors
    per tensor:
        u32         name_len
        bytes       name (utf-8)
        u8          dtype   (0=f32, 1=i8, 2=u8, 3=i32, 4=i64)
        u32         ndim
        u64 * ndim  dims
        bytes       raw data (C order)

Rust counterpart: ``rust/src/tensor/ntz.rs`` (round-trip tested on both sides).
"""

import struct

import numpy as np

MAGIC = b"NTZ1"

_DTYPES = {0: np.float32, 1: np.int8, 2: np.uint8, 3: np.int32, 4: np.int64}
_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


def save(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype == np.float64:
                arr = arr.astype(np.float32)
            code = _CODES[arr.dtype]
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", code))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def load(path: str) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"{path}: bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<I", f.read(4))
            name = f.read(nl).decode("utf-8")
            (code,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim))
            dt = np.dtype(_DTYPES[code])
            count = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(count * dt.itemsize), dtype=dt)
            out[name] = data.reshape(dims).copy()
    return out

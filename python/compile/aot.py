"""AOT export: lower every L2 graph to HLO *text* + write the manifest.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Exported graph inventory (see DESIGN.md §4): per model —

    embed.b{B}                 tokens -> x0
    block_fwd.b{B}             float block forward (fOut stream)
    block_fwd_q.{grp}.b{B}     quantized block forward (qOut stream, eval)
    block_taps.b{CB}           GPTQ Hessian tap activations
    head.b{B}                  final norm + tied logits
    channel_stats.b{CB}        float-target (mu, var)
    tweak_step.{grp}           fused NT iteration (loss+grad+Adam)
    tweak_step_mse / _kl       Table-9 loss ablation (nt-small, pc only)
    xtx.{K}                    Gram matrix for Hessian accumulation

and, unless `--no-decode`, the incremental-decode set (KV-cached serving;
recorded under the manifest's `decode` key with the per-layer cache shape
[n_head, seq, d_head] so the runtime can allocate sessions):

    block_fwd_kv.b{B}          prefill: block forward + per-head K/V
    block_fwd_q_kv.{grp}.b{B}  quantized prefill
    embed_dec.b{B}             one-token embed at per-row positions
    block_dec.b{B}             one-token float block step over the cache
    block_dec_q.{grp}.b{B}     one-token quantized block step
    head_dec.b{B}              one-token final norm + tied logits

{grp} ranges over the exported quantization grains, default
pc (per-channel) / g32 / g64 / g128 — the paper's two grains plus the
fine/coarse sweep neighbours.  Override with `--groups pc,g64`; whatever is
exported is recorded under the manifest's `groups` key, which the Rust
runtime parses to reject unexported grains at pipeline startup.
Inference graphs use the Pallas kernels; tweak graphs use the (pytest-
equivalent) jnp oracles because pallas_call has no VJP.

Every manifest graph entry records both the declared `inputs` and the
intended `outputs` signature (via `jax.eval_shape`, see `output_specs`);
`normtweak check --graphs` diffs that exporter intent against the lowered
HLO's ENTRY signature to catch drift (NT0502).
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import BATCH_BUCKETS, CALIB_BATCH, MODELS, ModelConfig

F32, I8, I32 = "f32", "i8", "i32"
_JNP = {F32: jnp.float32, I8: jnp.int8, I32: jnp.int32}

# numpy dtype name -> manifest dtype spelling, for the recorded output
# signatures (the Rust `graphs` lint parses these back into TensorSigs)
_MANIFEST_DTYPE = {"float32": F32, "int8": I8, "int32": I32,
                   "uint8": "u8", "int64": "i64"}

# eval/gen bucket + calibration bucket (B=1 is padded up by the coordinator)
EXPORT_BUCKETS = [b for b in BATCH_BUCKETS if b in (8, CALIB_BATCH)]

# Exported quantization grains: tag -> group size (0 == per-channel, i.e.
# one scale group spanning K).  Every tag here gets a `block_fwd_q` variant
# per bucket and one `tweak_step` variant; the dict is recorded verbatim in
# the manifest so the runtime knows exactly what was exported.
GROUPS = {"pc": 0, "g32": 32, "g64": 64, "g128": 128}


def parse_groups(spec: str) -> dict:
    """`"pc,g32,g64"` -> {"pc": 0, "g32": 32, "g64": 64} (strict)."""
    out = {}
    for tag in spec.split(","):
        tag = tag.strip()
        if not tag:
            continue
        if tag == "pc":
            out[tag] = 0
        elif tag.startswith("g") and tag[1:].isdigit() and int(tag[1:]) > 0:
            # canonicalize (g064 -> g64): the runtime derives tags as
            # `g{size}` from the scheme, so only that spelling resolves
            out[f"g{int(tag[1:])}"] = int(tag[1:])
        else:
            raise ValueError(
                f"bad grain tag {tag!r} (want `pc` or `g<N>`, e.g. g64)")
    if not out:
        raise ValueError("empty grain list")
    return out


def check_groups(cfg: ModelConfig, groups: dict) -> None:
    """Every grouped grain must divide both matmul K dims (d_model, d_ff)."""
    for tag, group in groups.items():
        for k in (cfg.d_model, cfg.d_ff):
            if group and k % group:
                raise ValueError(
                    f"{cfg.name}: grain {tag} (group={group}) does not "
                    f"divide K={k}")


def spec(shape, dtype=F32):
    return {"shape": list(shape), "dtype": dtype}


def arg(name, shape, dtype=F32):
    return {"name": name, **spec(shape, dtype)}


def output_specs(fn, in_specs):
    """The *intended* output signature of a graph: abstract-eval `fn` on
    the declared input specs (no lowering, no FLOPs).  Recorded per graph
    under the manifest's `outputs` key so the deep `normtweak check
    --graphs` pass can diff exporter intent against the lowered HLO's
    actual ENTRY signature (NT0502) without re-tracing anything."""
    shaped = [jax.ShapeDtypeStruct(tuple(s["shape"]), _JNP[s["dtype"]])
              for s in in_specs]
    outs = jax.tree_util.tree_leaves(jax.eval_shape(fn, *shaped))
    return [arg(f"out{i}", o.shape, _MANIFEST_DTYPE[str(o.dtype)])
            for i, o in enumerate(outs)]


def to_hlo_text(fn, in_specs):
    shaped = [jax.ShapeDtypeStruct(tuple(s["shape"]), _JNP[s["dtype"]])
              for s in in_specs]
    # keep_unused: the manifest promises every declared input is a real
    # parameter (block_taps, e.g., never touches wfc2 — jit would DCE it and
    # the Rust side would feed more buffers than the executable expects)
    lowered = jax.jit(fn, keep_unused=True).lower(*shaped)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


# --- per-graph arg builders ---------------------------------------------------


def float_weight_args(cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    out = [arg("ln1.g", (d,))]
    if cfg.norm == "layernorm":
        out.append(arg("ln1.b", (d,)))
    out += [arg("attn.wqkv", (d, 3 * d)), arg("attn.bqkv", (3 * d,)),
            arg("attn.wproj", (d, d)), arg("attn.bproj", (d,)),
            arg("ln2.g", (d,))]
    if cfg.norm == "layernorm":
        out.append(arg("ln2.b", (d,)))
    out += [arg("mlp.wfc1", (d, ff)), arg("mlp.bfc1", (ff,)),
            arg("mlp.wfc2", (ff, d)), arg("mlp.bfc2", (d,))]
    return out


def qweight_args(cfg: ModelConfig, group: int):
    d, ff = cfg.d_model, cfg.d_ff

    def g_of(k):
        return 1 if group == 0 else k // group

    out = [arg("ln1.g", (d,))]
    if cfg.norm == "layernorm":
        out.append(arg("ln1.b", (d,)))
    out += [arg("attn.wqkv.codes", (d, 3 * d), I8),
            arg("attn.wqkv.scales", (g_of(d), 3 * d)),
            arg("attn.bqkv", (3 * d,)),
            arg("attn.wproj.codes", (d, d), I8),
            arg("attn.wproj.scales", (g_of(d), d)),
            arg("attn.bproj", (d,)),
            arg("ln2.g", (d,))]
    if cfg.norm == "layernorm":
        out.append(arg("ln2.b", (d,)))
    out += [arg("mlp.wfc1.codes", (d, ff), I8),
            arg("mlp.wfc1.scales", (g_of(d), ff)),
            arg("mlp.bfc1", (ff,)),
            arg("mlp.wfc2.codes", (ff, d), I8),
            arg("mlp.wfc2.scales", (g_of(ff), d)),
            arg("mlp.bfc2", (d,))]
    return out


def norm_param_args(cfg: ModelConfig, prefix: str):
    d = cfg.d_model
    names = (("ln1.g", "ln1.b", "ln2.g", "ln2.b") if cfg.norm == "layernorm"
             else ("ln1.g", "ln2.g"))
    return [arg(f"{prefix}{n}", (d,)) for n in names]


def graph_defs(cfg: ModelConfig, groups: dict = None, decode: bool = True):
    """Yield (name, fn, input_args) for every graph of a model.

    `groups` maps grain tags to group sizes (default: the full GROUPS
    sweep); one `block_fwd_q` per (grain, bucket) and one `tweak_step` per
    grain are emitted.  `decode=False` skips the incremental-decode set
    (the runtime then falls back to full-context recompute per token).
    """
    groups = GROUPS if groups is None else groups
    check_groups(cfg, groups)
    d, ff, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq
    cb = CALIB_BATCH

    for b in EXPORT_BUCKETS:
        yield (f"embed.b{b}",
               lambda toks, te, pe, cfg=cfg: (M.embed(cfg, toks, te, pe),),
               [arg("tokens", (b, s), I32), arg("tok_emb", (v, d)),
                arg("pos_emb", (s, d))])

        wargs = float_weight_args(cfg)
        yield (f"block_fwd.b{b}",
               lambda x, *w, cfg=cfg: (M.block_fwd(cfg, x, list(w)),),
               [arg("x", (b, s, d))] + wargs)

        yield (f"head.b{b}",
               (lambda x, *rest, cfg=cfg:
                (M.head(cfg, x, list(rest[:-1]), rest[-1]),)),
               ([arg("x", (b, s, d)), arg("lnf.g", (d,))]
                + ([arg("lnf.b", (d,))] if cfg.norm == "layernorm" else [])
                + [arg("tok_emb", (v, d))]))

        for gname, group in groups.items():
            yield (f"block_fwd_q.{gname}.b{b}",
                   lambda x, *w, cfg=cfg: (M.block_fwd_q(cfg, x, list(w)),),
                   [arg("x", (b, s, d))] + qweight_args(cfg, group))

    if decode:
        h, dh = cfg.n_head, cfg.d_head
        for b in EXPORT_BUCKETS:
            wargs = float_weight_args(cfg)
            # prefill: full-context forward that also emits the K/V cache
            yield (f"block_fwd_kv.b{b}",
                   lambda x, *w, cfg=cfg: M.block_fwd_kv(cfg, x, list(w)),
                   [arg("x", (b, s, d))] + wargs)
            for gname, group in groups.items():
                yield (f"block_fwd_q_kv.{gname}.b{b}",
                       lambda x, *w, cfg=cfg: M.block_fwd_q_kv(cfg, x, list(w)),
                       [arg("x", (b, s, d))] + qweight_args(cfg, group))

            # one-token step graphs; KV caches ride last in both directions
            # (Runtime::run_carry threads them as carried state)
            cache_args = [arg("k_cache", (b, h, s, dh)),
                          arg("v_cache", (b, h, s, dh))]
            yield (f"embed_dec.b{b}",
                   (lambda toks, pos, te, pe, cfg=cfg:
                    (M.embed_dec(cfg, toks, pos, te, pe),)),
                   [arg("tokens", (b, 1), I32), arg("pos", (b,), I32),
                    arg("tok_emb", (v, d)), arg("pos_emb", (s, d))])
            yield (f"head_dec.b{b}",
                   (lambda x, *rest, cfg=cfg:
                    (M.head(cfg, x, list(rest[:-1]), rest[-1],
                            use_pallas=False),)),
                   ([arg("x", (b, 1, d)), arg("lnf.g", (d,))]
                    + ([arg("lnf.b", (d,))] if cfg.norm == "layernorm" else [])
                    + [arg("tok_emb", (v, d))]))
            yield (f"block_dec.b{b}",
                   (lambda x, pos, *rest, cfg=cfg, nw=len(wargs):
                    M.block_dec(cfg, x, pos, list(rest[:nw]),
                                rest[nw], rest[nw + 1])),
                   [arg("x", (b, 1, d)), arg("pos", (b,), I32)]
                   + wargs + cache_args)
            for gname, group in groups.items():
                qa = qweight_args(cfg, group)
                yield (f"block_dec_q.{gname}.b{b}",
                       (lambda x, pos, *rest, cfg=cfg, nq=len(qa):
                        M.block_dec_q(cfg, x, pos, list(rest[:nq]),
                                      rest[nq], rest[nq + 1])),
                       [arg("x", (b, 1, d)), arg("pos", (b,), I32)]
                       + qa + cache_args)

    yield (f"block_taps.b{cb}",
           lambda x, *w, cfg=cfg: M.block_taps(cfg, x, list(w)),
           [arg("x", (cb, s, d))] + float_weight_args(cfg))

    yield (f"channel_stats.b{cb}",
           lambda x: M.channel_stats_graph(x),
           [arg("x", (cb, s, d))])

    n_np = 4 if cfg.norm == "layernorm" else 2
    for gname, group in groups.items():
        qa = qweight_args(cfg, group)

        def tweak_fn(x, *rest, cfg=cfg, nq=len(qa), n_np=n_np):
            qw = list(rest[:nq])
            ms = list(rest[nq:nq + n_np])
            vs = list(rest[nq + n_np:nq + 2 * n_np])
            mu_f, var_f, lr, t = rest[nq + 2 * n_np:]
            return M.tweak_step(cfg, x, qw, ms, vs, mu_f, var_f, lr, t)

        yield (f"tweak_step.{gname}",
               tweak_fn,
               ([arg("x", (cb, s, d))] + qa
                + norm_param_args(cfg, "m.") + norm_param_args(cfg, "v.")
                + [arg("mu_f", (d,)), arg("var_f", (d,)),
                   arg("lr", (1,)), arg("t", (1,))]))

    # Table-9 loss-ablation graphs (nt-small only, per-channel — they need
    # the pc forward graphs, so they ride along only when pc is exported)
    if cfg.name == "nt-small" and "pc" in groups:
        qa = qweight_args(cfg, 0)
        for lname, lfn in (("mse", M.tweak_step_mse), ("kl", M.tweak_step_kl)):
            def abl_fn(x, *rest, cfg=cfg, nq=len(qa), n_np=n_np, lfn=lfn):
                qw = list(rest[:nq])
                ms = list(rest[nq:nq + n_np])
                vs = list(rest[nq + n_np:nq + 2 * n_np])
                y_f, lr, t = rest[nq + 2 * n_np:]
                return lfn(cfg, x, qw, ms, vs, y_f, lr, t)

            yield (f"tweak_step_{lname}.pc",
                   abl_fn,
                   ([arg("x", (cb, s, d))] + qa
                    + norm_param_args(cfg, "m.") + norm_param_args(cfg, "v.")
                    + [arg("y_f", (cb, s, d)), arg("lr", (1,)),
                       arg("t", (1,))]))

    rows = cb * s
    for k in sorted({d, ff}):
        yield (f"xtx.k{k}",
               lambda x2d: (M.xtx(x2d),),
               [arg("x", (rows, k))])


def export_model(cfg: ModelConfig, out_dir: str, manifest: dict,
                 groups: dict = None, decode: bool = True):
    for name, fn, in_args in graph_defs(cfg, groups, decode):
        t0 = time.time()
        fname = f"{cfg.name}.{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        text = to_hlo_text(fn, in_args)
        with open(path, "w") as f:
            f.write(text)
        manifest["graphs"].append({
            "model": cfg.name, "name": name, "file": fname,
            "inputs": in_args,
            "outputs": output_specs(fn, in_args),
        })
        print(f"[aot] {cfg.name}.{name}: {len(text) // 1024}KB "
              f"({time.time() - t0:.1f}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=list(MODELS))
    ap.add_argument("--groups", default=",".join(GROUPS),
                    help="comma-separated grain tags to export "
                         "(pc or g<N>; default: %(default)s)")
    ap.add_argument("--no-decode", action="store_true",
                    help="skip the incremental-decode graphs; the runtime "
                         "then falls back to full-context recompute per "
                         "generated token")
    args = ap.parse_args()
    groups = parse_groups(args.groups)
    for name in args.models:
        check_groups(MODELS[name], groups)
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "format": 1,
        "calib_batch": CALIB_BATCH,
        "buckets": EXPORT_BUCKETS,
        "groups": groups,
        "models": {name: {
            "n_layer": c.n_layer, "d_model": c.d_model, "n_head": c.n_head,
            "d_ff": c.d_ff, "vocab": c.vocab, "seq": c.seq, "norm": c.norm,
        } for name, c in MODELS.items() if name in args.models},
        "graphs": [],
    }
    if not args.no_decode:
        # the decode contract the Rust runtime parses: which buckets have
        # one-token step graphs, the slot-arena capacity (`slots` must be
        # a decode bucket >= the largest, so full-occupancy decode turns
        # have a step graph to dispatch), and the per-layer per-row cache
        # shape
        manifest["decode"] = {
            "buckets": EXPORT_BUCKETS,
            "slots": max(EXPORT_BUCKETS),
            "caches": {name: {
                "n_layer": c.n_layer,
                "shape": [c.n_head, c.seq, c.d_head],
            } for name, c in MODELS.items() if name in args.models},
        }
    for name in args.models:
        export_model(MODELS[name], args.out, manifest, groups,
                     decode=not args.no_decode)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest: {len(manifest['graphs'])} graphs")


if __name__ == "__main__":
    main()
